"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242]"""
from repro.configs.base import ModelConfig, register


@register
def zamba2_1p2b() -> ModelConfig:
    return ModelConfig(
        arch_id="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,              # assigned: GQA kv=32 (MHA-equivalent)
        d_ff=8192,
        vocab_size=32000,
        ssm_state=64,
        ssm_expand=2,
        ssm_head_dim=64,
        attn_every=6,               # shared attention block every 6th layer
        shared_attn=True,           # zamba trick: ONE attn block's weights reused
        sliding_window=8192,        # attention sub-block windows => long_500k native
        source="arXiv:2411.15242",
    )
