"""command-r-35b [dense] — GQA, no-bias. [hf:CohereForAI/c4ai-command-r-v01]"""
from repro.configs.base import ModelConfig, register


@register
def command_r_35b() -> ModelConfig:
    return ModelConfig(
        arch_id="command-r-35b",
        family="dense",
        n_layers=40,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22528,
        vocab_size=256000,
        qkv_bias=False,
        mlp_bias=False,
        act="swiglu",
        norm="layernorm",           # cohere uses LayerNorm (no bias)
        rope_theta=8_000_000.0,
        source="hf:CohereForAI/c4ai-command-r-v01",
    )
