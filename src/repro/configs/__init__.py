"""Arch registry. Importing this package registers every assigned
architecture plus the paper's own CNN families."""
from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    REGISTRY,
    ModelConfig,
    OptimizerConfig,
    RunConfig,
    ShapeConfig,
    SwarmConfig,
    get_config,
    register,
)

# assigned architectures ----------------------------------------------------
from repro.configs import (  # noqa: F401
    granite_3_2b,
    command_r_35b,
    zamba2_1p2b,
    deepseek_67b,
    kimi_k2_1t_a32b,
    whisper_base,
    llama4_maverick_400b_a17b,
    mamba2_370m,
    internvl2_26b,
    deepseek_7b,
    paper_cnns,
)

ASSIGNED_ARCHS = [
    "granite-3-2b",
    "command-r-35b",
    "zamba2-1.2b",
    "deepseek-67b",
    "kimi-k2-1t-a32b",
    "whisper-base",
    "llama4-maverick-400b-a17b",
    "mamba2-370m",
    "internvl2-26b",
    "deepseek-7b",
]
