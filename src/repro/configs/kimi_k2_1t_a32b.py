"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8.
[arXiv:2501.kimi2]"""
from repro.configs.base import ModelConfig, register


@register
def kimi_k2_1t_a32b() -> ModelConfig:
    return ModelConfig(
        arch_id="kimi-k2-1t-a32b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        d_ff=2048,                  # per-expert FFN width (paper table)
        vocab_size=163840,
        n_experts=384,
        top_k=8,
        n_dense_layers=1,           # first layer dense (DeepSeek-V3 lineage)
        n_shared_experts=1,
        capacity_factor=1.25,
        act="swiglu",
        norm="rmsnorm",
        param_dtype="bfloat16",     # 1T params: bf16 master + Adafactor
        source="arXiv:2501.kimi2",
    )
