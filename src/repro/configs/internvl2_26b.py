"""internvl2-26b [vlm] — InternViT (stub frontend) + InternLM2 backbone.
[arXiv:2404.16821]"""
from repro.configs.base import ModelConfig, register


@register
def internvl2_26b() -> ModelConfig:
    return ModelConfig(
        arch_id="internvl2-26b",
        family="vlm",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        vocab_size=92553,
        act="swiglu",
        norm="rmsnorm",
        frontend="vision",
        n_vision_tokens=256,        # projected patch embeddings (stub)
        source="arXiv:2404.16821",
    )
