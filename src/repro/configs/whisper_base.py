"""whisper-base [audio] — encoder-decoder transformer backbone; the
mel+conv frontend is the mandated stub (input_specs provides frame
embeddings). [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig, register


@register
def whisper_base() -> ModelConfig:
    return ModelConfig(
        arch_id="whisper-base",
        family="encdec",
        n_layers=6,                 # decoder layers
        n_encoder_layers=6,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_ff=2048,
        vocab_size=51865,
        act="gelu",
        norm="layernorm",
        qkv_bias=True,
        mlp_bias=True,
        is_encoder_decoder=True,
        encoder_seq=1500,
        frontend="audio",
        rope_theta=0.0,             # whisper uses learned/sinusoidal pos, not RoPE
        source="arXiv:2212.04356",
    )
