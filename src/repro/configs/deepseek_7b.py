"""deepseek-7b [dense] — llama-arch GQA kv=32. [arXiv:2401.02954]"""
from repro.configs.base import ModelConfig, register


@register
def deepseek_7b() -> ModelConfig:
    return ModelConfig(
        arch_id="deepseek-7b",
        family="dense",
        n_layers=30,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        d_ff=11008,
        vocab_size=102400,
        act="swiglu",
        norm="rmsnorm",
        source="arXiv:2401.02954",
    )
