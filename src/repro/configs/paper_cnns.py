"""The paper's own local models (BSO-SL §IV: SqueezeNet default;
RQ2 sweep over AlexNet / VGG16 / InceptionV3).

CNN configs reuse ModelConfig with family="cnn"; the cnn-specific
topology lives in ``repro.models.cnn`` keyed by ``arch_id``. These are
tiny, CPU-trainable models — the faithful-reproduction path.
"""
from repro.configs.base import ModelConfig, register

_COMMON = dict(
    family="cnn",
    n_layers=0, d_model=0,
    vocab_size=5,                    # 5 DR severity grades
    dtype="float32", param_dtype="float32",
    scan_layers=False,
)


@register
def squeezenet_dr() -> ModelConfig:
    return ModelConfig(arch_id="squeezenet-dr", source="arXiv:1602.07360", **_COMMON)


@register
def alexnet_dr() -> ModelConfig:
    return ModelConfig(arch_id="alexnet-dr", source="NeurIPS2012 AlexNet", **_COMMON)


@register
def vgg_dr() -> ModelConfig:
    return ModelConfig(arch_id="vgg-dr", source="arXiv:1409.1556", **_COMMON)


@register
def inception_dr() -> ModelConfig:
    return ModelConfig(arch_id="inception-dr", source="arXiv:1512.00567", **_COMMON)
