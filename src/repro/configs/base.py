"""Configuration system.

Every architecture is a :class:`ModelConfig`; every runnable experiment
is a :class:`RunConfig` (arch + input shape + mesh + optimizer). Arch
files under ``repro/configs/`` register themselves in :data:`REGISTRY`
so launchers can resolve ``--arch <id>``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Optional

# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm | cnn
    n_layers: int
    d_model: int
    n_heads: int = 0                 # 0 => attention-free
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0                # 0 => d_model // n_heads
    qkv_bias: bool = False
    mlp_bias: bool = False
    act: str = "swiglu"              # swiglu | gelu
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_every: int = 1               # MoE layer every N layers
    n_dense_layers: int = 0          # leading dense layers (DeepSeek/Kimi style)
    n_shared_experts: int = 0
    router_aux_weight: float = 0.01

    # --- SSM (Mamba2 / SSD, arXiv:2405.21060) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128

    # --- hybrid (Zamba2, arXiv:2411.15242): shared attention block every N ---
    attn_every: int = 0              # 0 => no interleaved attention (pure ssm)
    shared_attn: bool = False        # one attention block's weights reused

    # --- encoder-decoder (Whisper, arXiv:2212.04356) ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500          # post-conv audio frames at full config

    # --- modality frontend stub ---
    frontend: str = "none"           # none | audio | vision
    n_vision_tokens: int = 256       # VLM: patch embeddings prepended to text

    # --- long-context / decode ---
    sliding_window: int = 0          # 0 => full attention
    attn_chunk_q: int = 0            # q-chunk for prefill (0 => default 1024)
    cache_dtype: str = ""            # KV-cache dtype ("" => dtype); e.g.
                                     # "float8_e4m3fn" for quantized serving
    moe_grouped_dispatch: bool = False  # data-local MoE dispatch (beyond-paper)
    moe_groups: int = 16             # dispatch groups (= data shards)
    vocab_round_to: int = 0          # pad vocab so the readout shards over
                                     # "model" (beyond-paper §Perf H2)
    microbatch_override: int = 0     # dry-run/§Perf: grad-accum steps
    fsdp_over_pod: bool = True       # False: pure-DP pod axis (weights
                                     # replicated per pod) — §Perf H4
    cache_ring: bool = False         # sliding-window decode with a true
                                     # O(window) ring-buffer KV cache
                                     # (serving feature; the dry-run keeps
                                     # the mandated seq_len cache)

    # --- runtime ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    scan_layers: bool = True
    remat: str = "none"              # none | full | dots
    use_pallas: bool = False         # TPU kernels; dry-run lowers jnp path

    # provenance
    source: str = ""

    def __post_init__(self):
        if self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # derived -----------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        if self.vocab_round_to <= 0:
            return self.vocab_size
        r = self.vocab_round_to
        return ((self.vocab_size + r - 1) // r) * r

    @property
    def d_inner(self) -> int:        # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def smoke(self) -> "ModelConfig":
        """Reduced same-family variant for CPU smoke tests."""
        d = min(self.d_model, 128)
        heads = min(self.n_heads, 4) if self.n_heads else 0
        kv = min(self.n_kv_heads, heads) if self.n_kv_heads else 0
        return replace(
            self,
            arch_id=self.arch_id + "-smoke",
            n_layers=2,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            d_model=d,
            n_heads=heads,
            n_kv_heads=max(kv, 1) if heads else 0,
            head_dim=(d // heads) if heads else 0,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_dense_layers=min(self.n_dense_layers, 1),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=32,
            attn_every=2 if self.attn_every else 0,
            encoder_seq=64,
            n_vision_tokens=8 if self.frontend == "vision" else self.n_vision_tokens,
            sliding_window=0,
            dtype="float32",
            param_dtype="float32",
            scan_layers=False,
            remat="none",
        )


# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


INPUT_SHAPES: Dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"              # sgd | momentum | adam | adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    momentum: float = 0.9
    grad_clip: float = 1.0
    state_dtype: str = "float32"


@dataclass(frozen=True)
class SwarmConfig:
    """Paper §III hyper-parameters."""
    n_clients: int = 14
    n_clusters: int = 3              # paper §IV.C
    p1: float = 0.9                  # center-replacement threshold
    p2: float = 0.8                  # center-swap threshold
    local_epochs: int = 1
    local_steps: Optional[int] = None
    rounds: int = 10
    kmeans_iters: int = 20
    stat_granularity: str = "tensor"  # tensor | layer — distribution summary level


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    swarm: SwarmConfig = field(default_factory=SwarmConfig)
    microbatch: int = 0              # 0 => no grad accumulation
    seed: int = 0


# ---------------------------------------------------------------------------
# registry

REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(fn: Callable[[], ModelConfig]) -> Callable[[], ModelConfig]:
    cfg = fn()
    REGISTRY[cfg.arch_id] = fn
    return fn


def get_config(arch_id: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (triggers arch registration)
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(REGISTRY)}")
    return REGISTRY[arch_id]()


def asdict(cfg) -> dict:
    return dataclasses.asdict(cfg)
