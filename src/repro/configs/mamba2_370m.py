"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, register


@register
def mamba2_370m() -> ModelConfig:
    return ModelConfig(
        arch_id="mamba2-370m",
        family="ssm",
        n_layers=48,
        d_model=1024,
        n_heads=0,                  # attention-free
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,            # 2048/64 = 32 SSD heads
        ssm_conv_width=4,
        ssm_chunk=128,
        norm="rmsnorm",
        tie_embeddings=True,
        source="arXiv:2405.21060",
    )
