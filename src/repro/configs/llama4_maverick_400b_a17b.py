"""llama4-maverick-400b-a17b [moe] — 128 experts top-1, interleaved
dense/MoE, early fusion. [hf:meta-llama/Llama-4-Scout-17B-16E]"""
from repro.configs.base import ModelConfig, register


@register
def llama4_maverick_400b_a17b() -> ModelConfig:
    return ModelConfig(
        arch_id="llama4-maverick-400b-a17b",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=202048,
        n_experts=128,
        top_k=1,
        moe_every=2,                # MoE every other layer (maverick-style)
        n_shared_experts=1,
        capacity_factor=1.25,
        act="swiglu",
        norm="rmsnorm",
        param_dtype="bfloat16",
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
    )
