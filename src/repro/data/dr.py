"""Synthetic Diabetic-Retinopathy dataset, Table-I-exact.

The real APTOS-2019 Kaggle dataset is not available offline (repro band
2/5 — data gate), so we *simulate* it: the clinic×grade sample counts
below are copied verbatim from the paper's Table I (3,657 images,
14 clinics, 5 severity grades). Images are generated with
class-conditional structure so that models actually learn:

  * a fundus-like dark circular field,
  * grade-dependent count/intensity of bright lesion-like blobs
    (microaneurysms/exudates proxy) — monotone in severity,
  * a clinic-specific colour tint + resolution blur, simulating
    different fundus cameras (the paper's non-IID feature argument).

The 80/10/10 train/val/test split per clinic follows §IV.A.
"""
from __future__ import annotations

import numpy as np

# Paper Table I: rows = grades 0..4, cols = clinics C1..C14.
TABLE_I = np.array(
    [
        #  C1   C2   C3   C4  C5   C6   C7  C8  C9 C10 C11 C12 C13 C14
        [   2,  31, 901, 351,  0, 231, 279,  0,  0,  0,  0,  0,  0, 10],  # NoDR(0)
        [  13, 234,  19,   0, 13,  44,   7,  2, 13, 18,  0,  6,  1,  0],  # Mild(1)
        [ 307, 233,  39,   0, 91, 165,   1, 63, 28, 11, 33,  3, 22,  0],  # Moderate(2)
        [  32,  60,   2,   0,  6,  47,   0,  9,  1,  4,  5, 21,  3,  2],  # Severe(3)
        [  56,  80,  13,   0, 31,  46,   0, 18, 19, 19,  4,  4,  2,  2],  # Proliferative(4)
    ],
    dtype=np.int64,
)

N_CLINICS = TABLE_I.shape[1]
N_GRADES = TABLE_I.shape[0]
CLINIC_TOTALS = TABLE_I.sum(axis=0)          # [410, 638, 974, ...]
assert int(CLINIC_TOTALS.sum()) == 3657


def scale_table(data_scale: int, table: np.ndarray = None,
                min_count: int = 2) -> np.ndarray:
    """Table-I sample counts divided by ``data_scale`` for CPU-sized
    runs, with every *nonzero* cell floored at ``min_count`` so no
    clinic/grade pair vanishes (empty val/test splits break Eq. 3).

    The floor is a distortion: once ``data_scale`` exceeds a cell's
    count / ``min_count``, that cell stops shrinking while larger cells
    continue to, so rare grades become over-represented relative to the
    paper's class balance. Rather than silently benchmarking a
    different label skew, warn with the fraction of cells pinned at the
    floor — the caller can then judge whether the scale is still a
    faithful miniature.
    """
    table = TABLE_I if table is None else table
    if data_scale < 1:
        raise ValueError(f"data_scale must be >= 1, got {data_scale}")
    if data_scale == 1:
        return table.copy()              # the paper-exact counts, unfloored
    nonzero = table > 0
    scaled = table // data_scale
    clamped = nonzero & (scaled < min_count)
    if clamped.any():
        import warnings
        warnings.warn(
            f"data_scale={data_scale} pins {int(clamped.sum())}/"
            f"{int(nonzero.sum())} nonzero Table-I cells at the "
            f"min_count={min_count} floor; class balance is distorted "
            "(rare grades over-represented vs the paper's Table I)",
            RuntimeWarning, stacklevel=2)
    return np.maximum(scaled, nonzero.astype(np.int64) * min_count)


def bucket_clients(sizes, max_buckets: int = 4, strategy: str = "pow2"):
    """Group client indices into at most ``max_buckets`` size buckets —
    the host-side half of the ragged swarm layout
    (:class:`repro.core.engine.BucketedSwarmData`): each bucket's
    clients are padded only to the bucket's own maximum instead of the
    global maximum, so pad waste on a Table-I-skewed swarm drops from
    pad-to-global-max to pad-to-bucket-max.

    * ``strategy="pow2"`` — clients grouped by the next power of two
      above their size; when that yields more than ``max_buckets``
      groups, adjacent (in ceiling order) groups merge greedily by
      least added pad rows.
    * ``strategy="quantile"`` — clients sorted by size and split into
      ``max_buckets`` equal-count groups (quantile edges).

    Returns a list of int64 index arrays (ascending client ids within a
    bucket; buckets ordered by ascending size ceiling) that partition
    ``range(len(sizes))``. Deterministic in its inputs.
    """
    sizes = np.asarray(sizes, np.int64)
    if sizes.ndim != 1 or len(sizes) == 0:
        raise ValueError("sizes must be a non-empty 1-D sequence")
    if max_buckets < 1:
        raise ValueError(f"max_buckets must be >= 1, got {max_buckets}")
    if strategy == "pow2":
        ceil = 2 ** np.ceil(np.log2(np.maximum(sizes, 1))).astype(np.int64)
        groups = [np.flatnonzero(ceil == c) for c in np.unique(ceil)]
        # merge adjacent groups (ascending ceilings) until <= max_buckets,
        # each time picking the pair whose merge adds the fewest pad rows
        # (every client in the smaller group pads up to the larger
        # group's max size)
        while len(groups) > max_buckets:
            costs = [len(groups[i]) * (int(sizes[groups[i + 1]].max())
                                       - int(sizes[groups[i]].max()))
                     for i in range(len(groups) - 1)]
            i = int(np.argmin(costs))
            groups[i:i + 2] = [np.sort(np.concatenate(groups[i:i + 2]))]
        return groups
    if strategy == "quantile":
        order = np.argsort(sizes, kind="stable")
        parts = np.array_split(order, min(max_buckets, len(sizes)))
        return [np.sort(p) for p in parts if len(p)]
    raise ValueError(f"unknown bucket strategy {strategy!r} "
                     "(one of 'pow2', 'quantile')")


def _render_image(rng: np.random.Generator, grade: int, clinic: int,
                  size: int) -> np.ndarray:
    """One synthetic fundus image (size, size, 3) float32 in [0, 1]."""
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float32)
    cy = cx = (size - 1) / 2.0
    r = np.sqrt((yy - cy) ** 2 + (xx - cx) ** 2) / (size / 2.0)

    # fundus field: dark red disc with radial falloff
    base = np.clip(1.0 - r, 0.0, 1.0)[..., None]
    img = base * np.array([0.55, 0.25, 0.10], np.float32)
    # heavy sensor noise: the real APTOS task is hard — local models with
    # tens of images must NOT be able to trivially separate grades,
    # otherwise the paper's local-vs-federated gap inverts (see
    # EXPERIMENTS.md §Paper-results calibration note)
    img += rng.normal(0.0, 0.12, size=(size, size, 3)).astype(np.float32)

    # grade-dependent lesions: more + slightly brighter blobs at higher
    # severity (subtle: comparable to the noise floor per image)
    n_lesions = grade * 2
    for _ in range(n_lesions):
        ang = rng.uniform(0, 2 * np.pi)
        rad = rng.uniform(0.15, 0.85) * (size / 2.0)
        ly, lx = cy + rad * np.sin(ang), cx + rad * np.cos(ang)
        sigma = rng.uniform(0.8, 2.2) * size / 32.0
        blob = np.exp(-(((yy - ly) ** 2 + (xx - lx) ** 2) / (2 * sigma ** 2)))
        intensity = 0.22 + 0.06 * grade
        img += blob[..., None] * np.array([intensity, intensity * 0.9, 0.1], np.float32)

    # clinic camera signature: deterministic mild tint
    tint_rng = np.random.default_rng(1000 + clinic)
    tint = tint_rng.uniform(0.95, 1.05, size=3).astype(np.float32)
    img = img * tint
    return np.clip(img, 0.0, 1.0)


def make_dr_swarm_data(image_size: int = 32, seed: int = 0,
                       table: np.ndarray = None):
    """Returns a list of 14 clinic dicts:
    {"train": (X, y), "val": (X, y), "test": (X, y), "n_train": int}
    with X float32 (N, H, W, 3), y int32 (N,).
    """
    table = TABLE_I if table is None else table
    rng = np.random.default_rng(seed)
    clinics = []
    for c in range(table.shape[1]):
        imgs, labels = [], []
        for grade in range(table.shape[0]):
            for _ in range(int(table[grade, c])):
                imgs.append(_render_image(rng, grade, c, image_size))
                labels.append(grade)
        X = np.stack(imgs).astype(np.float32)
        y = np.asarray(labels, np.int32)
        perm = rng.permutation(len(y))
        X, y = X[perm], y[perm]
        n = len(y)
        n_tr = max(int(round(0.8 * n)), 1)
        n_val = max(int(round(0.1 * n)), 1)
        n_val = min(n_val, n - n_tr - 1) if n - n_tr - 1 >= 1 else max(n - n_tr - 1, 0)
        n_val = max(n_val, 1) if n - n_tr >= 2 else 0
        splits = {
            "train": (X[:n_tr], y[:n_tr]),
            "val": (X[n_tr:n_tr + max(n_val, 1)], y[n_tr:n_tr + max(n_val, 1)]),
            "test": (X[n_tr + max(n_val, 1):], y[n_tr + max(n_val, 1):]),
        }
        # tiny clinics: guarantee non-empty val/test by reusing train tail
        for k in ("val", "test"):
            if len(splits[k][1]) == 0:
                splits[k] = (X[-2:], y[-2:])
        clinics.append({**splits, "n_train": len(splits["train"][1])})
    return clinics


def batch_iterator(X: np.ndarray, y: np.ndarray, batch: int, rng: np.random.Generator):
    """Shuffled minibatch epochs; pads the tail by wraparound so every
    batch has a static shape (jit-friendly)."""
    n = len(y)
    idx = rng.permutation(n)
    for start in range(0, n, batch):
        take = idx[start:start + batch]
        if len(take) < batch:
            take = np.concatenate([take, idx[: batch - len(take)]])
        yield X[take], y[take]
