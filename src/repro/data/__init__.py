from repro.data.dr import TABLE_I, make_dr_swarm_data  # noqa: F401
from repro.data.tokens import make_lm_batches, make_token_swarm_data  # noqa: F401
