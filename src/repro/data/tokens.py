"""Synthetic token streams for the LM-architecture swarm experiments.

Sequences follow a clinic-specific order-1 Markov chain over the vocab,
so (a) next-token prediction is learnable, and (b) different "clients"
have genuinely non-IID token distributions — the same property the DR
clinics have. Used by the ~100M end-to-end training example and the
LM smoke tests.
"""
from __future__ import annotations

import numpy as np


def _client_transition(vocab: int, client: int, sharpness: float = 8.0):
    rng = np.random.default_rng(7_000 + client)
    logits = rng.normal(size=(vocab, vocab)) * sharpness / np.sqrt(vocab)
    # favour a client-specific cyclic structure => learnable + non-IID
    shift = 1 + (client % 7)
    for i in range(vocab):
        logits[i, (i + shift) % vocab] += sharpness
    p = np.exp(logits - logits.max(axis=1, keepdims=True))
    return p / p.sum(axis=1, keepdims=True)


def sample_tokens(vocab: int, n_seqs: int, seq_len: int, client: int = 0,
                  seed: int = 0) -> np.ndarray:
    P = _client_transition(vocab, client)
    rng = np.random.default_rng(seed * 977 + client)
    out = np.empty((n_seqs, seq_len), np.int32)
    state = rng.integers(0, vocab, size=n_seqs)
    cdf = P.cumsum(axis=1)
    for t in range(seq_len):
        out[:, t] = state
        u = rng.random(n_seqs)
        state = (cdf[state] > u[:, None]).argmax(axis=1)
    return out


def make_lm_batches(vocab: int, batch: int, seq_len: int, n_batches: int,
                    client: int = 0, seed: int = 0):
    for b in range(n_batches):
        toks = sample_tokens(vocab, batch, seq_len + 1, client, seed + b)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_token_swarm_data(n_clients: int, vocab: int, n_seqs: int,
                          seq_len: int, seed: int = 0):
    """Per-client LM datasets mirroring the DR swarm-data structure."""
    clients = []
    for c in range(n_clients):
        toks = sample_tokens(vocab, n_seqs + 4, seq_len + 1, c, seed)
        tr, va, te = toks[:n_seqs], toks[n_seqs:n_seqs + 2], toks[n_seqs + 2:]
        clients.append({
            "train": (tr[:, :-1], tr[:, 1:]),
            "val": (va[:, :-1], va[:, 1:]),
            "test": (te[:, :-1], te[:, 1:]),
            "n_train": n_seqs,
        })
    return clients
