"""Learning-rate schedules (pure functions step -> lr)."""
from __future__ import annotations

import jax.numpy as jnp


def make_schedule(name: str, base_lr: float, *, warmup: int = 0,
                  total_steps: int = 0, min_ratio: float = 0.1):
    if name == "constant":
        def sched(step):
            if warmup > 0:
                return base_lr * jnp.minimum(1.0, (step + 1) / warmup)
            return jnp.asarray(base_lr)
        return sched
    if name == "cosine":
        if total_steps <= 0:
            raise ValueError("cosine schedule needs total_steps")

        def sched(step):
            warm = jnp.minimum(1.0, (step + 1) / max(warmup, 1))
            prog = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
            cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
            return base_lr * warm * cos
        return sched
    raise ValueError(f"unknown schedule '{name}'")
