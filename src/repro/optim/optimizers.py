"""Hand-rolled optimizers (optax is not available in this environment).

All optimizers share one interface::

    opt = make_optimizer(OptimizerConfig(...))
    state = opt.init(params)
    new_params, new_state = opt.update(grads, state, params, lr)

State is a plain pytree so it shards with the same logical-axis rules as
the parameters (critical for the ≥100B dry-runs). Adafactor keeps
factored second moments so the 1T-param config's optimizer state is
O(rows+cols) per matrix instead of O(rows*cols).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.utils.tree import tree_global_norm


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable
    update: Callable                 # (grads, state, params, lr) -> (params, state)


def _clip_by_global_norm(grads, max_norm):
    if max_norm <= 0:
        return grads
    norm = tree_global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads)


# cached on the frozen config: the closures are pure, and reusing the
# instance lets the engine's static EngineConfig (which embeds the
# optimizer) hash equal across trainers — one compiled round program
# instead of one per construction
@functools.cache
def make_optimizer(cfg: OptimizerConfig) -> Optimizer:
    if cfg.name == "sgd":
        return _sgd(cfg)
    if cfg.name == "momentum":
        return _momentum(cfg)
    if cfg.name in ("adam", "adamw"):
        return _adam(cfg, decoupled_wd=(cfg.name == "adamw"))
    if cfg.name == "adafactor":
        return _adafactor(cfg)
    raise ValueError(f"unknown optimizer '{cfg.name}'")


# ---------------------------------------------------------------------------

def _sgd(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        grads = _clip_by_global_norm(grads, cfg.grad_clip)
        new_params = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
        return new_params, {"step": state["step"] + 1}

    return Optimizer("sgd", init, update)


def _momentum(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=cfg.state_dtype), params),
        }

    def update(grads, state, params, lr):
        grads = _clip_by_global_norm(grads, cfg.grad_clip)
        mu = jax.tree.map(
            lambda m, g: cfg.momentum * m + g.astype(m.dtype), state["mu"], grads)
        new_params = jax.tree.map(lambda p, m: p - lr * m.astype(p.dtype), params, mu)
        return new_params, {"step": state["step"] + 1, "mu": mu}

    return Optimizer("momentum", init, update)


def _adam(cfg: OptimizerConfig, decoupled_wd: bool) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, dtype=cfg.state_dtype)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(z, params),
            "v": jax.tree.map(z, params),
        }

    def update(grads, state, params, lr):
        grads = _clip_by_global_norm(grads, cfg.grad_clip)
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        bc1 = 1.0 - cfg.b1 ** t
        bc2 = 1.0 - cfg.b2 ** t
        m = jax.tree.map(lambda m_, g: cfg.b1 * m_ + (1 - cfg.b1) * g.astype(m_.dtype),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: cfg.b2 * v_ + (1 - cfg.b2) * jnp.square(g.astype(v_.dtype)),
                         state["v"], grads)

        def step_fn(p, m_, v_):
            upd = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + cfg.eps)
            if decoupled_wd and cfg.weight_decay > 0:
                upd = upd + cfg.weight_decay * p.astype(upd.dtype)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

        new_params = jax.tree.map(step_fn, params, m, v)
        return new_params, {"step": step, "m": m, "v": v}

    return Optimizer("adamw" if decoupled_wd else "adam", init, update)


def _adafactor(cfg: OptimizerConfig) -> Optimizer:
    """Factored second-moment estimator (Shazeer & Stern 2018), the
    standard choice for ≥100B training. No first moment (momentum-free),
    row/col factored v for rank>=2 leaves."""
    eps2 = 1e-30

    def init(params):
        def leaf_state(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], dtype=jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], dtype=jnp.float32),
                }
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

        return {"step": jnp.zeros((), jnp.int32),
                "v": jax.tree.map(leaf_state, params, is_leaf=lambda x: hasattr(x, "ndim"))}

    def update(grads, state, params, lr):
        grads = _clip_by_global_norm(grads, cfg.grad_clip)
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** -0.8       # standard adafactor decay schedule

        def leaf_update(p, g, s):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps2
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps2)
                precond = (vr[..., :, None] / denom[..., :, None]) * vc[..., None, :]
                upd = g / (jnp.sqrt(precond) + cfg.eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                upd = g / (jnp.sqrt(v) + cfg.eps)
                new_s = {"v": v}
            # update clipping (RMS<=1), as in the paper
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + eps2)
            upd = upd / jnp.maximum(1.0, rms)
            if cfg.weight_decay > 0:
                upd = upd + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), new_s

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state["v"])
        out = [leaf_update(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        new_v = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
        return new_params, {"step": step, "v": new_v}

    return Optimizer("adafactor", init, update)
