from repro.sharding.rules import (  # noqa: F401
    AxisRules,
    DEFAULT_RULES,
    build_param_specs,
    logical_axes_for_path,
    shard_act,
    spec_for,
    use_sharding,
)
