"""Logical-axis sharding rules (MaxText-style).

Model code never mentions mesh axes. Parameters are given *logical*
axes derived from their tree path + shape; activations are annotated
with :func:`shard_act`. A rule table maps logical axes to physical mesh
axes, with automatic divisibility fallback (an axis that does not divide
the mesh size is left unsharded rather than failing to lower).

Physical mesh axes:
  pod    — outer swarm-client / pure-DP axis (multi-pod only)
  data   — batch / FSDP axis
  model  — tensor-parallel axis
"""
from __future__ import annotations

import contextlib
import re
import threading
from dataclasses import dataclass, field
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Logical-axis rule table
# ---------------------------------------------------------------------------

#: logical axis -> tuple of mesh axes (tried in order, divisibility-checked)
DEFAULT_LOGICAL_TO_PHYSICAL = {
    # activations
    "batch": ("pod", "data"),
    "seq": (),
    "cache_seq": ("data", "model"),   # distributed KV cache (decode); for
                                      # B=1 long_500k batch frees "data" and
                                      # the cache shards 256-way over seq
    "embed": (),                       # activation embed dim stays local
    "act_heads": ("model",),
    "act_mlp": ("model",),
    "act_experts": ("model",),
    # parameters
    "p_embed": ("data", "pod"),        # FSDP axes for weights (512-way with pods)
    "p_mlp": ("model",),
    "p_heads": ("model",),
    "p_kv": ("model",),
    "p_vocab": ("model",),
    "p_experts": ("model",),
    "p_state": (),
    "p_conv": (),
    "layers": (),                      # scanned-layer leading axis
    "clients": ("pod",),               # swarm client axis (fleet regime)
}


@dataclass(frozen=True)
class AxisRules:
    logical_to_physical: dict = field(default_factory=lambda: dict(DEFAULT_LOGICAL_TO_PHYSICAL))

    def physical(self, logical: Optional[str], mesh: Mesh, dim_size: int,
                 taken: set) -> Optional[tuple]:
        """Resolve one logical axis to mesh axes, respecting divisibility
        and never assigning the same mesh axis twice within one spec."""
        if logical is None:
            return None
        candidates = self.logical_to_physical.get(logical, ())
        chosen = []
        prod = 1
        for ax in candidates:
            if ax in taken or ax not in mesh.shape:
                continue
            n = mesh.shape[ax]
            if dim_size % (prod * n) == 0:
                chosen.append(ax)
                prod *= n
        if not chosen:
            return None
        for ax in chosen:
            taken.add(ax)
        return tuple(chosen)


DEFAULT_RULES = AxisRules()

# ---------------------------------------------------------------------------
# Parameter path -> logical axes
# ---------------------------------------------------------------------------

# Longest-suffix match on the parameter path. Order matters: first hit wins.
_PARAM_PATH_RULES = [
    # embeddings / heads
    (r"embedding/table$",            ("p_vocab", "p_embed")),
    (r"pos_embedding/table$",        (None, "p_embed")),
    (r"lm_head/w$",                  ("p_embed", "p_vocab")),
    # attention
    (r"attn.*/wq$",                  ("p_embed", "p_heads")),
    (r"attn.*/wk$",                  ("p_embed", "p_kv")),
    (r"attn.*/wv$",                  ("p_embed", "p_kv")),
    (r"attn.*/wo$",                  ("p_heads", "p_embed")),
    (r"attn.*/(bq|bk|bv)$",          ("p_heads",)),
    (r"attn.*/bo$",                  ("p_embed",)),
    # dense mlp
    (r"mlp/wi$",                     ("p_embed", "p_mlp")),
    (r"mlp/wg$",                     ("p_embed", "p_mlp")),
    (r"mlp/wo$",                     ("p_mlp", "p_embed")),
    (r"mlp/(bi|bg)$",                ("p_mlp",)),
    (r"mlp/bo$",                     ("p_embed",)),
    # moe
    (r"router/w$",                   ("p_embed", "p_experts")),
    (r"router/b$",                   ("p_experts",)),
    (r"experts/wi$",                 ("p_experts", "p_embed", "p_mlp")),
    (r"experts/wg$",                 ("p_experts", "p_embed", "p_mlp")),
    (r"experts/wo$",                 ("p_experts", "p_mlp", "p_embed")),
    (r"shared_expert/wi$",           ("p_embed", "p_mlp")),
    (r"shared_expert/wg$",           ("p_embed", "p_mlp")),
    (r"shared_expert/wo$",           ("p_mlp", "p_embed")),
    # mamba2 / ssm
    (r"ssm/in_proj$",                ("p_embed", "p_heads")),
    (r"ssm/out_proj$",               ("p_heads", "p_embed")),
    (r"ssm/conv_w$",                 ("p_conv", "p_heads")),
    (r"ssm/conv_b$",                 ("p_heads",)),
    (r"ssm/(A_log|dt_bias|D)$",      ("p_heads",)),
    (r"ssm/norm_scale$",             ("p_heads",)),
    # decode caches
    (r"(^|/)(k|v)$",                 ("batch", "cache_seq", "p_kv", None)),
    (r"cross_(k|v)$",                (None, "batch", None, "p_kv", None)),
    (r"(^|/)conv$",                  ("batch", None, "p_heads")),
    (r"(^|/)state$",                 ("batch", "p_heads", None, None)),
    # norms / scalars
    (r"(scale|bias)$",               (None,)),
    # cnn (tiny models — replicate)
    (r"conv\d*/w$",                  (None, None, None, None)),
    (r"conv\d*/b$",                  (None,)),
    (r"fc\d*/w$",                    ("p_embed", None)),
    (r"fc\d*/b$",                    (None,)),
]


def logical_axes_for_path(path: str, ndim: int) -> tuple:
    """Map a parameter path to its logical axes.

    Handles the stacked-layer case: if the matched rule has one fewer
    axis than the array rank, a leading "layers" axis is assumed.
    Adafactor's factored states (…/vr = parent minus last dim,
    …/vc = parent minus second-to-last) inherit the parent weight's axes.
    """
    if path.endswith("/vr"):
        parent = logical_axes_for_path(path[:-3], ndim + 1)
        return parent[:-1]
    if path.endswith("/vc"):
        parent = logical_axes_for_path(path[:-3], ndim + 1)
        return parent[:-2] + parent[-1:]
    for pat, axes in _PARAM_PATH_RULES:
        if re.search(pat, path):
            if len(axes) == ndim:
                return axes
            if len(axes) == ndim - 1:
                return ("layers",) + axes
            # rank mismatch (e.g. fused projections): replicate
            return (None,) * ndim
    return (None,) * ndim


def spec_for(logical_axes: tuple, mesh: Mesh, shape: tuple,
             rules: AxisRules = DEFAULT_RULES) -> P:
    """Build a PartitionSpec from logical axes, with divisibility checks."""
    taken: set = set()
    parts = []
    for logical, dim in zip(logical_axes, shape):
        phys = rules.physical(logical, mesh, dim, taken)
        if phys is None:
            parts.append(None)
        elif len(phys) == 1:
            parts.append(phys[0])
        else:
            parts.append(phys)
    return P(*parts)


def build_param_specs(params, mesh: Mesh, rules: AxisRules = DEFAULT_RULES):
    """Pytree of PartitionSpec mirroring ``params``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(_key_name(k) for k in path)
        axes = logical_axes_for_path(pstr, leaf.ndim)
        specs.append(spec_for(axes, mesh, leaf.shape, rules))
    return jax.tree_util.tree_unflatten(treedef, specs)


def build_param_shardings(params, mesh: Mesh, rules: AxisRules = DEFAULT_RULES):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        build_param_specs(params, mesh, rules))


def _key_name(k):
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    return str(k)


# ---------------------------------------------------------------------------
# Activation sharding context
# ---------------------------------------------------------------------------

class _ShardingCtx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: AxisRules = DEFAULT_RULES


_CTX = _ShardingCtx()


@contextlib.contextmanager
def use_sharding(mesh: Optional[Mesh], rules: AxisRules = DEFAULT_RULES):
    """Activate activation-sharding constraints for model code traced
    inside this context. Without it, :func:`shard_act` is a no-op (the
    CPU sim regime)."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def shard_act(x, *logical_axes):
    """with_sharding_constraint by logical axis names; no-op outside
    a use_sharding() context."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"shard_act: {len(logical_axes)} axes for rank-{x.ndim} array")
    spec = spec_for(logical_axes, mesh, x.shape, _CTX.rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
