"""Model assembly for every assigned architecture family.

Families:
  dense   — [attn + MLP] x L                       (granite, command-r, deepseek)
  moe     — [attn + MoE] with dense interleave      (kimi-k2, llama4)
  ssm     — [Mamba2/SSD] x L                        (mamba2-370m)
  hybrid  — Mamba2 backbone + ONE shared attention
            block applied every ``attn_every`` layers (zamba2)
  encdec  — whisper: bidirectional encoder + causal decoder w/ cross-attn
  vlm     — internvl: stub patch embeddings prepended to the token stream

Deep homogeneous stacks are scanned (``cfg.scan_layers``) with stacked
parameter pytrees — essential to keep 95-layer lower/compile tractable —
and support jax.checkpoint remat policies.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    _dtype,
    apply_embedding,
    apply_mlp,
    apply_norm,
    init_embedding,
    init_mlp,
    init_norm,
    logits_from_embedding,
    dense_init,
    sinusoidal_positions,
)
from repro.models.moe import apply_moe, init_moe
from repro.sharding import shard_act

# ---------------------------------------------------------------------------
# layer plan


def layer_kinds(cfg: ModelConfig):
    """Per-layer block kind list."""
    kinds = []
    for i in range(cfg.n_layers):
        if cfg.family == "ssm":
            kinds.append("ssm")
        elif cfg.family == "hybrid":
            # mamba block everywhere; shared attention applied after every
            # ``attn_every``-th layer (weights shared — the zamba2 trick)
            kinds.append("ssm")
        elif cfg.family == "moe":
            if i < cfg.n_dense_layers or (cfg.moe_every > 1 and i % cfg.moe_every == 0):
                kinds.append("dense")
            else:
                kinds.append("moe")
        else:
            kinds.append("dense")
    return kinds


# ---------------------------------------------------------------------------
# single block


def init_block(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 4)
    if kind == "ssm":
        return {"ssm_norm": init_norm(cfg, cfg.d_model),
                "ssm": ssm_lib.init_ssm(ks[0], cfg)}
    p = {
        "attn_norm": init_norm(cfg, cfg.d_model),
        "attn": attn_lib.init_attention(ks[0], cfg),
        "mlp_norm": init_norm(cfg, cfg.d_model),
    }
    if kind == "moe":
        p["moe"] = init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg)
    return p


def block_cache(cfg: ModelConfig, kind: str, batch: int, max_seq: int):
    if kind == "ssm":
        return ssm_lib.init_ssm_cache(cfg, batch)
    return attn_lib.init_kv_cache(cfg, batch, max_seq)


def apply_block(p, x, cfg: ModelConfig, kind: str, *, positions=None,
                cache=None, pos=None, sliding_window=0):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "ssm":
        h = apply_norm(p["ssm_norm"], x, cfg)
        if cache is None:
            out, _ = ssm_lib.apply_ssm(p["ssm"], h, cfg)
            new_cache = None
        else:
            out, new_cache = ssm_lib.apply_ssm_decode(p["ssm"], h, cache, cfg)
        return x + out, new_cache, aux

    h = apply_norm(p["attn_norm"], x, cfg)
    if cache is None:
        a = attn_lib.attend_full(p["attn"], h, cfg, positions=positions,
                                 causal=True, sliding_window=sliding_window)
        new_cache = None
    else:
        a, new_cache = attn_lib.attend_decode(p["attn"], h, cache, pos, cfg,
                                              sliding_window=sliding_window)
    x = x + a
    h = apply_norm(p["mlp_norm"], x, cfg)
    if kind == "moe":
        m, aux = apply_moe(p["moe"], h, cfg)
    else:
        m = apply_mlp(p["mlp"], h, cfg)
    return x + m, new_cache, aux


# ---------------------------------------------------------------------------
# decoder-only LM (dense / moe / ssm / hybrid / vlm)


def init_lm(key, cfg: ModelConfig):
    kinds = layer_kinds(cfg)
    ks = jax.random.split(key, cfg.n_layers + 4)
    params: Dict[str, Any] = {
        "embedding": init_embedding(ks[0], cfg.padded_vocab, cfg.d_model, cfg),
        "final_norm": init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": dense_init(ks[1], (cfg.d_model, cfg.padded_vocab),
                                             dtype=_dtype(cfg.param_dtype))}
    if cfg.family == "hybrid":
        params["shared_attn"] = {
            "attn_norm": init_norm(cfg, cfg.d_model),
            "attn": attn_lib.init_attention(ks[2], cfg),
            "mlp_norm": init_norm(cfg, cfg.d_model),
            "mlp": init_mlp(ks[3], cfg),
        }
    if cfg.scan_layers and _scannable(cfg):
        params["layers"] = _init_scanned(ks[4:], cfg, kinds)
    else:
        params["blocks"] = [init_block(ks[4 + i], cfg, kinds[i])
                            for i in range(cfg.n_layers)]
    return params


def _scannable(cfg: ModelConfig) -> bool:
    """Scan homogeneous (or fixed-period) decoder stacks."""
    return cfg.family in ("dense", "moe", "vlm")


def _scan_plan(cfg: ModelConfig):
    """(prefix_kinds, period_kinds, n_periods): leading unscanned layers
    (e.g. kimi's dense layer 0) + a repeating scanned period."""
    kinds = layer_kinds(cfg)
    prefix = kinds[: cfg.n_dense_layers]
    body = kinds[cfg.n_dense_layers:]
    period = max(cfg.moe_every, 1) if cfg.family == "moe" else 1
    if len(body) % period:
        extra = len(body) % period
        prefix = prefix + body[:extra]
        body = body[extra:]
    period_kinds = body[:period]
    return prefix, period_kinds, len(body) // period


def _init_scanned(keys, cfg: ModelConfig, kinds):
    prefix, period_kinds, n_periods = _scan_plan(cfg)
    out: Dict[str, Any] = {"prefix": [init_block(keys[i], cfg, prefix[i])
                                      for i in range(len(prefix))]}
    base = len(prefix)
    for j, kind in enumerate(period_kinds):
        ks = jax.random.split(keys[base + j], n_periods)
        stacked = jax.vmap(lambda k: init_block(k, cfg, kind))(ks)
        out[f"period{j}"] = stacked
    return out


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


def _forward_layers(params, x, cfg: ModelConfig, *, positions):
    """Train/prefill pass through the decoder stack.
    Returns (x, total_aux)."""
    kinds = layer_kinds(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    sw = cfg.sliding_window

    if "blocks" in params:
        def run_block(p, h, kind):
            y, _, aux = apply_block(p, h, cfg, kind, positions=positions,
                                    sliding_window=sw)
            return y, aux

        for i, p in enumerate(params["blocks"]):
            fn = _maybe_remat(
                lambda p_, h_, kind=kinds[i]: run_block(p_, h_, kind), cfg)
            x, aux = fn(p, x)
            aux_total = aux_total + aux
            if cfg.family == "hybrid" and cfg.attn_every and \
                    (i + 1) % cfg.attn_every == 0:
                fn = _maybe_remat(
                    lambda p_, h_: run_block(p_, h_, "dense"), cfg)
                x, _ = fn(params["shared_attn"], x)
        return x, aux_total

    # scanned
    lp = params["layers"]
    prefix, period_kinds, n_periods = _scan_plan(cfg)
    for i, p in enumerate(lp["prefix"]):
        x, _, aux = apply_block(p, x, cfg, prefix[i], positions=positions,
                                sliding_window=sw)
        aux_total = aux_total + aux

    def body(carry, stacked):
        h, aux_acc = carry
        for j, kind in enumerate(period_kinds):
            h, _, aux = apply_block(stacked[f"period{j}"], h, cfg, kind,
                                    positions=positions, sliding_window=sw)
            aux_acc = aux_acc + aux
        return (h, aux_acc), None

    body = _maybe_remat(body, cfg)
    stacked_xs = {k: v for k, v in lp.items() if k.startswith("period")}
    (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), stacked_xs)
    return x, aux_total


def _decode_layers(params, x, caches, pos, cfg: ModelConfig):
    kinds = layer_kinds(cfg)
    sw = cfg.sliding_window

    if "blocks" in params:
        new_caches = []
        ci = 0
        for i, p in enumerate(params["blocks"]):
            x, nc, _ = apply_block(p, x, cfg, kinds[i], cache=caches[ci], pos=pos,
                                   sliding_window=sw)
            new_caches.append(nc)
            ci += 1
            if cfg.family == "hybrid" and cfg.attn_every and \
                    (i + 1) % cfg.attn_every == 0:
                x, nc2, _ = apply_block(params["shared_attn"], x, cfg, "dense",
                                        cache=caches[ci], pos=pos,
                                        sliding_window=cfg.sliding_window or 0)
                new_caches.append(nc2)
                ci += 1
        return x, new_caches

    lp = params["layers"]
    prefix, period_kinds, n_periods = _scan_plan(cfg)
    new_prefix = []
    for i, p in enumerate(lp["prefix"]):
        x, nc, _ = apply_block(p, x, cfg, prefix[i], cache=caches["prefix"][i],
                               pos=pos, sliding_window=sw)
        new_prefix.append(nc)

    def body(h, xs):
        stacked, cache = xs
        ncs = {}
        for j, kind in enumerate(period_kinds):
            h, nc, _ = apply_block(stacked[f"period{j}"], h, cfg, kind,
                                   cache=cache[f"period{j}"], pos=pos,
                                   sliding_window=sw)
            ncs[f"period{j}"] = nc
        return h, ncs

    stacked_xs = {k: v for k, v in lp.items() if k.startswith("period")}
    x, new_stacked = jax.lax.scan(body, x, (stacked_xs, caches["body"]))
    return x, {"prefix": new_prefix, "body": new_stacked}


def init_lm_cache(cfg: ModelConfig, batch: int, max_seq: int):
    kinds = layer_kinds(cfg)
    if cfg.scan_layers and _scannable(cfg):
        prefix, period_kinds, n_periods = _scan_plan(cfg)
        body = {}
        for j, kind in enumerate(period_kinds):
            one = block_cache(cfg, kind, batch, max_seq)
            body[f"period{j}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (n_periods,) + x.shape), one)
        return {"prefix": [block_cache(cfg, prefix[i], batch, max_seq)
                           for i in range(len(prefix))],
                "body": body}
    caches = []
    for i, kind in enumerate(kinds):
        caches.append(block_cache(cfg, kind, batch, max_seq))
        if cfg.family == "hybrid" and cfg.attn_every and (i + 1) % cfg.attn_every == 0:
            caches.append(block_cache(cfg, "dense", batch, max_seq))
    return caches


def _readout(params, x, cfg: ModelConfig):
    x = apply_norm(params["final_norm"], x, cfg)
    if cfg.tie_embeddings:
        logits = logits_from_embedding(params["embedding"], x)
    else:
        logits = x @ params["lm_head"]["w"].astype(x.dtype)
    return shard_act(logits, *(("batch",) + ("seq",) * (logits.ndim - 2) + ("act_mlp",)))


def lm_forward(params, batch, cfg: ModelConfig):
    """Train/prefill forward. batch: {"tokens": (B,S)[, "vision_embed"]}.
    Returns (logits, aux)."""
    tokens = batch["tokens"]
    x = apply_embedding(params["embedding"], tokens, cfg)
    if cfg.family == "vlm":
        ve = batch["vision_embed"].astype(x.dtype)          # (B, n_vis, d)
        x = jnp.concatenate([ve, x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = shard_act(x, "batch", "seq", "embed")
    x, aux = _forward_layers(params, x, cfg, positions=positions)
    logits = _readout(params, x, cfg)
    if cfg.family == "vlm":
        logits = logits[:, batch["vision_embed"].shape[1]:, :]
    return logits, aux


def lm_decode_step(params, tokens, caches, pos, cfg: ModelConfig):
    """tokens: (B,1) int32; pos: () int32 — or (B,) int32 for per-row
    positions (continuous batching; attention families only — the SSM
    recurrence is position-free so it needs no change).
    Returns (logits (B,1,V), caches)."""
    x = apply_embedding(params["embedding"], tokens, cfg)
    x = shard_act(x, "batch", "seq", "embed")
    x, new_caches = _decode_layers(params, x, caches, pos, cfg)
    return _readout(params, x, cfg), new_caches


# ---------------------------------------------------------------------------
# chunked prefill (serving): one forward + KV-cache writeback


def _prefill_block(p, x, cache, pos0, cfg: ModelConfig, kind: str):
    """One decoder block over a prompt chunk with cache writeback.
    Attention-backed kinds only: an SSM state updated by padded prompt
    tails cannot be masked after the fact, so ssm/hybrid serve through
    the per-token path instead."""
    if kind != "dense" and kind != "moe":
        raise NotImplementedError(
            f"chunked prefill supports attention blocks, got '{kind}'")
    h = apply_norm(p["attn_norm"], x, cfg)
    a, new_cache = attn_lib.attend_prefill(p["attn"], h, cache, pos0, cfg,
                                           sliding_window=cfg.sliding_window)
    x = x + a
    h = apply_norm(p["mlp_norm"], x, cfg)
    if kind == "moe":
        m, _ = apply_moe(p["moe"], h, cfg)
    else:
        m = apply_mlp(p["mlp"], h, cfg)
    return x + m, new_cache


def lm_prefill(params, tokens, caches, pos0, cfg: ModelConfig):
    """Chunked prefill: tokens (B,C) at positions ``pos0..pos0+C-1``,
    ONE forward through the stack writing each layer's K/V into the
    cache. Returns (logits (B,C,V), caches) — the caller gathers the
    logit row at each request's last real prompt token. Replaces the
    per-token teacher-forcing loop (C decode dispatches -> 1 program).
    """
    kinds = layer_kinds(cfg)
    x = apply_embedding(params["embedding"], tokens, cfg)
    x = shard_act(x, "batch", "seq", "embed")

    if "blocks" in params:
        new_caches = []
        for i, p in enumerate(params["blocks"]):
            x, nc = _prefill_block(p, x, caches[i], pos0, cfg, kinds[i])
            new_caches.append(nc)
        return _readout(params, x, cfg), new_caches

    lp = params["layers"]
    prefix, period_kinds, n_periods = _scan_plan(cfg)
    new_prefix = []
    for i, p in enumerate(lp["prefix"]):
        x, nc = _prefill_block(p, x, caches["prefix"][i], pos0, cfg,
                               prefix[i])
        new_prefix.append(nc)

    def body(h, xs):
        stacked, cache = xs
        ncs = {}
        for j, kind in enumerate(period_kinds):
            h, nc = _prefill_block(stacked[f"period{j}"], h,
                                   cache[f"period{j}"], pos0, cfg, kind)
            ncs[f"period{j}"] = nc
        return h, ncs

    stacked_xs = {k: v for k, v in lp.items() if k.startswith("period")}
    x, new_stacked = jax.lax.scan(body, x, (stacked_xs, caches["body"]))
    return _readout(params, x, cfg), {"prefix": new_prefix,
                                      "body": new_stacked}


# ---------------------------------------------------------------------------
# encoder-decoder (whisper)


def init_encdec(key, cfg: ModelConfig):
    ks = jax.random.split(key, cfg.n_encoder_layers + cfg.n_layers + 3)
    enc_blocks = [init_block(ks[i], cfg, "dense")
                  for i in range(cfg.n_encoder_layers)]
    dec_blocks = []
    base = cfg.n_encoder_layers
    for i in range(cfg.n_layers):
        kb = jax.random.split(ks[base + i], 2)
        b = init_block(kb[0], cfg, "dense")
        b["cross_norm"] = init_norm(cfg, cfg.d_model)
        b["cross_attn"] = attn_lib.init_attention(kb[1], cfg)
        dec_blocks.append(b)
    return {
        "embedding": init_embedding(ks[-2], cfg.padded_vocab, cfg.d_model, cfg),
        "enc_final_norm": init_norm(cfg, cfg.d_model),
        "final_norm": init_norm(cfg, cfg.d_model),
        "encoder": enc_blocks,
        "decoder": dec_blocks,
        "lm_head": {"w": dense_init(ks[-1], (cfg.d_model, cfg.padded_vocab),
                                    dtype=_dtype(cfg.param_dtype))},
    }


def encdec_encode(params, audio_embed, cfg: ModelConfig):
    """audio_embed: (B, S_enc, d) — the mandated frontend stub output."""
    B, S, d = audio_embed.shape
    x = audio_embed.astype(_dtype(cfg.dtype)) + \
        sinusoidal_positions(S, d).astype(_dtype(cfg.dtype))[None]
    x = shard_act(x, "batch", "seq", "embed")
    for p in params["encoder"]:
        h = apply_norm(p["attn_norm"], x, cfg)
        x = x + attn_lib.attend_full(p["attn"], h, cfg, causal=False)
        h = apply_norm(p["mlp_norm"], x, cfg)
        x = x + apply_mlp(p["mlp"], h, cfg)
    return apply_norm(params["enc_final_norm"], x, cfg)


def encdec_forward(params, batch, cfg: ModelConfig):
    """batch: {"audio_embed": (B,S_enc,d), "tokens": (B,S_dec)}."""
    enc = encdec_encode(params, batch["audio_embed"], cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = apply_embedding(params["embedding"], tokens, cfg)
    x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    for p in params["decoder"]:
        h = apply_norm(p["attn_norm"], x, cfg)
        x = x + attn_lib.attend_full(p["attn"], h, cfg, positions=positions,
                                     causal=True)
        h = apply_norm(p["cross_norm"], x, cfg)
        x = x + attn_lib.attend_full(p["cross_attn"], h, cfg, x_kv=enc,
                                     causal=False)
        h = apply_norm(p["mlp_norm"], x, cfg)
        x = x + apply_mlp(p["mlp"], h, cfg)
    x = apply_norm(params["final_norm"], x, cfg)
    return x @ params["lm_head"]["w"].astype(x.dtype), jnp.zeros((), jnp.float32)


def init_encdec_cache(cfg: ModelConfig, batch: int, max_seq: int):
    """Self-attn KV per decoder layer + precomputed cross K/V."""
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    dt = _dtype(cfg.dtype)
    return {
        "self": [attn_lib.init_kv_cache(cfg, batch, max_seq)
                 for _ in range(cfg.n_layers)],
        "cross_k": jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq, KV, hd), dt),
        "cross_v": jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq, KV, hd), dt),
    }


def encdec_decode_step(params, tokens, caches, pos, cfg: ModelConfig):
    x = apply_embedding(params["embedding"], tokens, cfg)
    # sinusoidal positional term at position ``pos``
    d = cfg.d_model
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / (10_000.0 ** (2 * dim / d))
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None, :]
    x = x + pe.astype(x.dtype)
    new_self = []
    for i, p in enumerate(params["decoder"]):
        h = apply_norm(p["attn_norm"], x, cfg)
        a, nc = attn_lib.attend_decode(p["attn"], h, caches["self"][i], pos, cfg)
        x = x + a
        new_self.append(nc)
        # cross attention against precomputed encoder K/V
        h = apply_norm(p["cross_norm"], x, cfg)
        ck, cv = caches["cross_k"][i], caches["cross_v"][i]
        a, _ = attn_lib.attend_decode(
            p["cross_attn"], h, {"k": ck, "v": cv},
            jnp.asarray(cfg.encoder_seq - 1, jnp.int32), cfg, update_cache=False)
        x = x + a
        h = apply_norm(p["mlp_norm"], x, cfg)
        x = x + apply_mlp(p["mlp"], h, cfg)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = x @ params["lm_head"]["w"].astype(x.dtype)
    return logits, {**caches, "self": new_self}
