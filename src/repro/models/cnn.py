"""The paper's local-model CNN family, in pure JAX.

BSO-SL §IV uses SqueezeNet as the default client model and sweeps
AlexNet / VGG16 / InceptionV3 for the model-agnostic claim (RQ2).
SqueezeNet is implemented faithfully (fire modules, conv classifier,
global average pooling — arXiv:1602.07360); the others are
reduced-depth members of their families sized for the 32px synthetic
DR images (the paper itself resizes per-clinic images to one dimension).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

N_CLASSES = 5


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    std = jnp.sqrt(2.0 / fan_in)
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * std


def conv2d(x, w, b=None, stride=1, padding="SAME"):
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if b is not None:
        out = out + b
    return out


def maxpool(x, k=2, stride=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, stride, stride, 1), "VALID")


def global_avg_pool(x):
    return jnp.mean(x, axis=(1, 2))


# ---------------------------------------------------------------------------
# SqueezeNet (faithful: fire modules = squeeze 1x1 -> expand 1x1 + 3x3)

_SQUEEZE_PLAN = [  # (squeeze, expand) per fire module
    (8, 32), (8, 32), (16, 64), (16, 64),
]


def _init_fire(key, cin, s, e):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "squeeze": {"w": _conv_init(k1, 1, 1, cin, s), "b": jnp.zeros((s,))},
        "e1": {"w": _conv_init(k2, 1, 1, s, e), "b": jnp.zeros((e,))},
        "e3": {"w": _conv_init(k3, 3, 3, s, e), "b": jnp.zeros((e,))},
    }


def _apply_fire(p, x):
    s = jax.nn.relu(conv2d(x, p["squeeze"]["w"], p["squeeze"]["b"]))
    e1 = conv2d(s, p["e1"]["w"], p["e1"]["b"])
    e3 = conv2d(s, p["e3"]["w"], p["e3"]["b"])
    return jax.nn.relu(jnp.concatenate([e1, e3], axis=-1))


def init_squeezenet(key):
    ks = jax.random.split(key, len(_SQUEEZE_PLAN) + 2)
    params = {"conv1": {"w": _conv_init(ks[0], 3, 3, 3, 32), "b": jnp.zeros((32,))}}
    cin = 32
    for i, (s, e) in enumerate(_SQUEEZE_PLAN):
        params[f"fire{i}"] = _init_fire(ks[1 + i], cin, s, e)
        cin = 2 * e
    # squeezenet-style conv classifier (1x1 conv -> GAP)
    params["conv_cls"] = {"w": _conv_init(ks[-1], 1, 1, cin, N_CLASSES),
                          "b": jnp.zeros((N_CLASSES,))}
    return params


def apply_squeezenet(params, x):
    x = jax.nn.relu(conv2d(x, params["conv1"]["w"], params["conv1"]["b"], stride=2))
    for i in range(len(_SQUEEZE_PLAN)):
        x = _apply_fire(params[f"fire{i}"], x)
        if i % 2 == 1:
            x = maxpool(x)
    x = conv2d(x, params["conv_cls"]["w"], params["conv_cls"]["b"])
    return global_avg_pool(x)


# ---------------------------------------------------------------------------
# AlexNet-style


def init_alexnet(key):
    ks = jax.random.split(key, 5)
    return {
        "conv1": {"w": _conv_init(ks[0], 5, 5, 3, 48), "b": jnp.zeros((48,))},
        "conv2": {"w": _conv_init(ks[1], 3, 3, 48, 96), "b": jnp.zeros((96,))},
        "conv3": {"w": _conv_init(ks[2], 3, 3, 96, 96), "b": jnp.zeros((96,))},
        # GAP head instead of the classic flatten-FC so the model accepts
        # any clinic image size (the paper resizes per-clinic anyway)
        "fc1": {"w": dense_init(ks[3], (96, 256)), "b": jnp.zeros((256,))},
        "fc2": {"w": dense_init(ks[4], (256, N_CLASSES)), "b": jnp.zeros((N_CLASSES,))},
    }


def apply_alexnet(p, x):
    x = maxpool(jax.nn.relu(conv2d(x, p["conv1"]["w"], p["conv1"]["b"], stride=2)))
    x = maxpool(jax.nn.relu(conv2d(x, p["conv2"]["w"], p["conv2"]["b"])))
    x = jax.nn.relu(conv2d(x, p["conv3"]["w"], p["conv3"]["b"]))
    x = global_avg_pool(x)
    x = jax.nn.relu(x @ p["fc1"]["w"] + p["fc1"]["b"])
    return x @ p["fc2"]["w"] + p["fc2"]["b"]


# ---------------------------------------------------------------------------
# VGG-style (conv-conv-pool blocks)


def init_vgg(key):
    ks = jax.random.split(key, 6)
    chans = [(3, 32), (32, 32), (32, 64), (64, 64)]
    p = {}
    for i, (ci, co) in enumerate(chans):
        p[f"conv{i}"] = {"w": _conv_init(ks[i], 3, 3, ci, co), "b": jnp.zeros((co,))}
    p["fc1"] = {"w": dense_init(ks[4], (64, 256)), "b": jnp.zeros((256,))}
    p["fc2"] = {"w": dense_init(ks[5], (256, N_CLASSES)), "b": jnp.zeros((N_CLASSES,))}
    return p


def apply_vgg(p, x):
    x = jax.nn.relu(conv2d(x, p["conv0"]["w"], p["conv0"]["b"]))
    x = maxpool(jax.nn.relu(conv2d(x, p["conv1"]["w"], p["conv1"]["b"])))
    x = jax.nn.relu(conv2d(x, p["conv2"]["w"], p["conv2"]["b"]))
    x = maxpool(jax.nn.relu(conv2d(x, p["conv3"]["w"], p["conv3"]["b"])))
    x = global_avg_pool(x)                       # size-agnostic head
    x = jax.nn.relu(x @ p["fc1"]["w"] + p["fc1"]["b"])
    return x @ p["fc2"]["w"] + p["fc2"]["b"]


# ---------------------------------------------------------------------------
# Inception-style (parallel 1x1 / 3x3 / 5x5 / pool branches)


def _init_inception_block(key, cin, c1, c3, c5, cp):
    ks = jax.random.split(key, 4)
    return {
        "b1": {"w": _conv_init(ks[0], 1, 1, cin, c1), "b": jnp.zeros((c1,))},
        "b3": {"w": _conv_init(ks[1], 3, 3, cin, c3), "b": jnp.zeros((c3,))},
        "b5": {"w": _conv_init(ks[2], 5, 5, cin, c5), "b": jnp.zeros((c5,))},
        "bp": {"w": _conv_init(ks[3], 1, 1, cin, cp), "b": jnp.zeros((cp,))},
    }


def _apply_inception_block(p, x):
    b1 = jax.nn.relu(conv2d(x, p["b1"]["w"], p["b1"]["b"]))
    b3 = jax.nn.relu(conv2d(x, p["b3"]["w"], p["b3"]["b"]))
    b5 = jax.nn.relu(conv2d(x, p["b5"]["w"], p["b5"]["b"]))
    pool = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 3, 3, 1), (1, 1, 1, 1), "SAME")
    bp = jax.nn.relu(conv2d(pool, p["bp"]["w"], p["bp"]["b"]))
    return jnp.concatenate([b1, b3, b5, bp], axis=-1)


def init_inception(key):
    ks = jax.random.split(key, 4)
    p = {"conv1": {"w": _conv_init(ks[0], 3, 3, 3, 32), "b": jnp.zeros((32,))}}
    p["inc0"] = _init_inception_block(ks[1], 32, 16, 24, 8, 8)      # -> 56
    p["inc1"] = _init_inception_block(ks[2], 56, 24, 32, 12, 12)    # -> 80
    p["fc"] = {"w": dense_init(ks[3], (80, N_CLASSES)), "b": jnp.zeros((N_CLASSES,))}
    return p


def apply_inception(p, x):
    x = maxpool(jax.nn.relu(conv2d(x, p["conv1"]["w"], p["conv1"]["b"], stride=2)))
    x = _apply_inception_block(p["inc0"], x)
    x = maxpool(x)
    x = _apply_inception_block(p["inc1"], x)
    x = global_avg_pool(x)
    return x @ p["fc"]["w"] + p["fc"]["b"]


# ---------------------------------------------------------------------------

CNN_ZOO = {
    "squeezenet-dr": (init_squeezenet, apply_squeezenet),
    "alexnet-dr": (init_alexnet, apply_alexnet),
    "vgg-dr": (init_vgg, apply_vgg),
    "inception-dr": (init_inception, apply_inception),
}


def init_cnn(key, cfg: ModelConfig):
    return CNN_ZOO[cfg.arch_id][0](key)


def apply_cnn(params, images, cfg: ModelConfig):
    return CNN_ZOO[cfg.arch_id][1](params, images)
