"""Mamba2 / SSD block (state-space duality, arXiv:2405.21060).

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
compute inside chunks of length ``cfg.ssm_chunk`` + a linear recurrence
over chunk states — the TPU-friendly formulation (dense MXU matmuls per
chunk, one small scan across chunks). Decode is the O(1) recurrent
update: this is why the ssm/hybrid archs run long_500k natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dtype, dense_init
from repro.sharding import shard_act

# group count for B/C projections (mamba2 default 1 in small models)
G = 1


def _dims(cfg: ModelConfig):
    d_inner = cfg.d_inner
    H = cfg.n_ssm_heads
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_dim = d_inner + 2 * G * N
    d_in_proj = 2 * d_inner + 2 * G * N + H
    return d_inner, H, P, N, conv_dim, d_in_proj


def init_ssm(key, cfg: ModelConfig):
    d = cfg.d_model
    d_inner, H, P, N, conv_dim, d_in_proj = _dims(cfg)
    pd = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d, d_in_proj), dtype=pd),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv_width, conv_dim), in_axis=0, dtype=pd),
        "conv_b": jnp.zeros((conv_dim,), pd),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(pd),
        "dt_bias": jnp.zeros((H,), pd),
        "D": jnp.ones((H,), pd),
        "norm_scale": jnp.ones((d_inner,), pd),
        "out_proj": dense_init(ks[3], (d_inner, d), dtype=pd),
    }


def _split_proj(proj, cfg: ModelConfig):
    d_inner, H, P, N, conv_dim, _ = _dims(cfg)
    z = proj[..., :d_inner]
    xBC = proj[..., d_inner:d_inner + conv_dim]
    dt = proj[..., d_inner + conv_dim:]
    return z, xBC, dt


def _gated_norm(p, y, z, cfg: ModelConfig):
    y = y * jax.nn.silu(z.astype(jnp.float32))
    rms = jnp.sqrt(jnp.mean(jnp.square(y), axis=-1, keepdims=True) + cfg.norm_eps)
    return y / rms * p["norm_scale"].astype(jnp.float32)


def apply_ssm(p, x, cfg: ModelConfig, initial_state=None,
              initial_conv=None, return_carry=False):
    """Chunked SSD forward. x: (B, S, d) with S % ssm_chunk == 0.

    Returns (y (B,S,d), final_state (B,H,P,N)); with ``return_carry``
    the second element is (final_state, conv_frames (B,w-1,conv_dim)) —
    together with ``initial_state``/``initial_conv`` this makes chunked
    prefill exactly equivalent to processing the whole sequence
    (tests/test_properties.py::test_ssd_is_causal_and_state_consistent).
    """
    Bsz, S, d = x.shape
    d_inner, H, P, N, conv_dim, _ = _dims(cfg)
    L = min(cfg.ssm_chunk, S)
    if S % L:
        raise ValueError(f"seq {S} not divisible by ssm_chunk {L}")
    nc = S // L
    dt_ = x.dtype

    proj = x @ p["in_proj"].astype(dt_)                     # (B,S,d_in_proj)
    z, xBC, dt_raw = _split_proj(proj, cfg)

    # causal depthwise conv over (x,B,C) channels; boundary frames come
    # from the previous chunk's carry when prefilling in pieces
    w = cfg.ssm_conv_width
    if initial_conv is None:
        initial_conv = jnp.zeros((Bsz, w - 1, conv_dim), dt_)
    pad = jnp.concatenate([initial_conv.astype(dt_), xBC], axis=1)
    final_conv = pad[:, -(w - 1):, :] if w > 1 else initial_conv
    conv = sum(pad[:, i:i + S, :] * p["conv_w"][i].astype(dt_) for i in range(w))
    xBC = jax.nn.silu(conv + p["conv_b"].astype(dt_))

    xs = xBC[..., :d_inner].reshape(Bsz, S, H, P)
    Bm = xBC[..., d_inner:d_inner + G * N].reshape(Bsz, S, G, N)
    Cm = xBC[..., d_inner + G * N:].reshape(Bsz, S, G, N)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))   # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))             # (H,) negative
    dA = dt * A                                              # (B,S,H) log-decay

    # --- chunk views ---
    xs_c = xs.reshape(Bsz, nc, L, H, P).astype(jnp.float32)
    B_c = Bm.reshape(Bsz, nc, L, G, N).astype(jnp.float32)
    C_c = Cm.reshape(Bsz, nc, L, G, N).astype(jnp.float32)
    dt_c = dt.reshape(Bsz, nc, L, H)
    dA_c = dA.reshape(Bsz, nc, L, H)
    cum = jnp.cumsum(dA_c, axis=2)                           # (B,nc,L,H)

    # --- intra-chunk (attention-like, causal) ---
    # decay[t,s] = exp(cum[t]-cum[s]), t>=s. Mask BEFORE the exp: for
    # t<s rel is positive and exp overflows, and where(mask, inf, 0)
    # produces NaN gradients (0 * inf) in the backward pass.
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,nc,L,L,H)
    mask = jnp.tril(jnp.ones((L, L), bool))[None, None, :, :, None]
    decay = jnp.exp(jnp.where(mask, rel, -1e30))
    cb = jnp.einsum("bclgn,bcsgn->bcls", C_c, B_c)           # (B,nc,L,L) (G=1)
    scores = cb[..., None] * decay * dt_c[:, :, None, :, :]  # (B,nc,L,L,H)
    y_intra = jnp.einsum("bclsh,bcshp->bclhp", scores, xs_c)

    # --- chunk states ---
    seg = jnp.exp(cum[:, :, -1:, :] - cum)                   # decay to chunk end
    weighted = xs_c * (seg * dt_c)[..., None]                # (B,nc,L,H,P)
    states = jnp.einsum("bclgn,bclhp->bchpn", B_c, weighted)  # (B,nc,H,P,N)

    # --- inter-chunk recurrence (scan over chunks) ---
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # (B,nc,H)
    if initial_state is None:
        initial_state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def scan_fn(carry, inp):
        s_c, dec = inp                                       # (B,H,P,N), (B,H)
        prev = carry
        new = dec[:, :, None, None] * prev + s_c
        return new, prev                                     # emit state *before* chunk

    states_t = jnp.moveaxis(states, 1, 0)                    # (nc,B,H,P,N)
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)                # (nc,B,H)
    final_state, prev_states = jax.lax.scan(scan_fn, initial_state.astype(jnp.float32),
                                            (states_t, decay_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)            # (B,nc,H,P,N)

    # --- inter-chunk contribution ---
    in_decay = jnp.exp(cum)                                  # decay from chunk start
    y_inter = jnp.einsum("bclgn,bchpn->bclhp", C_c, prev_states) * \
        in_decay[..., None]
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    y = y + xs.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(Bsz, S, d_inner)
    y = _gated_norm(p, y, z, cfg)
    y = shard_act(y, "batch", "seq", "act_heads")
    out = y.astype(dt_) @ p["out_proj"].astype(dt_)
    if return_carry:
        return out, (final_state, final_conv)
    return out, final_state


# ---------------------------------------------------------------------------
# decode


def init_ssm_cache(cfg: ModelConfig, batch: int):
    d_inner, H, P, N, conv_dim, _ = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), _dtype(cfg.dtype)),
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
    }


def apply_ssm_decode(p, x, cache, cfg: ModelConfig):
    """One-token recurrent step. x: (B, 1, d)."""
    Bsz = x.shape[0]
    d_inner, H, P, N, conv_dim, _ = _dims(cfg)
    dt_ = x.dtype

    proj = x[:, 0, :] @ p["in_proj"].astype(dt_)             # (B, d_in_proj)
    z, xBC, dt_raw = _split_proj(proj, cfg)

    # conv ring: shift in the new frame
    frames = jnp.concatenate([cache["conv"], xBC[:, None, :]], axis=1)  # (B,w,conv)
    conv = jnp.einsum("bwc,wc->bc", frames, p["conv_w"].astype(dt_))
    xBC = jax.nn.silu(conv + p["conv_b"].astype(dt_))
    new_conv = frames[:, 1:, :]

    xh = xBC[:, :d_inner].reshape(Bsz, H, P).astype(jnp.float32)
    Bm = xBC[:, d_inner:d_inner + G * N].reshape(Bsz, G, N).astype(jnp.float32)
    Cm = xBC[:, d_inner + G * N:].reshape(Bsz, G, N).astype(jnp.float32)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dec = jnp.exp(dt * A)                                    # (B,H)

    outer = jnp.einsum("bgn,bhp->bhpn", Bm, xh * dt[..., None])
    state = dec[:, :, None, None] * cache["state"] + outer
    y = jnp.einsum("bgn,bhpn->bhp", Cm, state)
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(Bsz, d_inner)
    y = _gated_norm(p, y, z, cfg)
    out = y.astype(dt_) @ p["out_proj"].astype(dt_)
    return out[:, None, :], {"conv": new_conv, "state": state}
