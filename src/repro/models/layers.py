"""Shared layer primitives: inits, norms, RoPE, MLPs.

Models are pure-JAX pytrees: ``init_*`` builds parameter dicts,
``apply``-style functions consume them. No flax in this environment.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding import shard_act


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def dense_init(key, shape, in_axis=-2, dtype=jnp.float32, scale=1.0):
    """LeCun-normal on the fan-in axis."""
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    std = scale / jnp.sqrt(jnp.maximum(fan_in, 1)).astype(jnp.float32)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# norms

def init_norm(cfg: ModelConfig, d: int):
    p = {"scale": jnp.ones((d,), _dtype(cfg.param_dtype))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), _dtype(cfg.param_dtype))
    return p


def apply_norm(p, x, cfg: ModelConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        rms = jnp.sqrt(jnp.mean(jnp.square(xf), axis=-1, keepdims=True) + cfg.norm_eps)
        out = xf / rms * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) / jnp.sqrt(var + cfg.norm_eps) * p["scale"].astype(jnp.float32)
        if "bias" in p:
            out = out + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings

def rope_frequencies(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    if theta <= 0:
        return x
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)          # (half,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                       # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int):
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLP

def init_mlp(key, cfg: ModelConfig):
    pd = _dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    d, ff = cfg.d_model, cfg.d_ff
    p = {"wi": dense_init(k1, (d, ff), dtype=pd), "wo": dense_init(k3, (ff, d), dtype=pd)}
    if cfg.act == "swiglu":
        p["wg"] = dense_init(k2, (d, ff), dtype=pd)
    if cfg.mlp_bias:
        p["bi"] = jnp.zeros((ff,), pd)
        p["bo"] = jnp.zeros((d,), pd)
    return p


def apply_mlp(p, x, cfg: ModelConfig):
    dt = x.dtype
    h = x @ p["wi"].astype(dt)
    if "bi" in p:
        h = h + p["bi"].astype(dt)
    if cfg.act == "swiglu":
        g = x @ p["wg"].astype(dt)
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = shard_act(h, *(("batch",) + ("seq",) * (h.ndim - 2) + ("act_mlp",)))
    out = h @ p["wo"].astype(dt)
    if "bo" in p:
        out = out + p["bo"].astype(dt)
    return out


# ---------------------------------------------------------------------------
# embeddings

def init_embedding(key, vocab: int, d: int, cfg: ModelConfig):
    return {"table": dense_init(key, (vocab, d), in_axis=-1,
                                dtype=_dtype(cfg.param_dtype))}


def apply_embedding(p, tokens, cfg: ModelConfig):
    out = jnp.take(p["table"].astype(_dtype(cfg.dtype)), tokens, axis=0)
    return out


def logits_from_embedding(p, x):
    """Tied read-out."""
    return x @ p["table"].astype(x.dtype).T
