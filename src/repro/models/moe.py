"""Mixture-of-Experts layer: top-k router + sort-based capacity dispatch.

TPU-native adaptation: instead of per-token gather loops (GPU style),
tokens are sorted by expert id and scattered into a static
(experts, capacity, d) buffer, so every expert runs one dense
(C, d) x (d, ff) matmul on the MXU. Experts are sharded over the
``model`` mesh axis (expert parallelism); the scatter/gather across the
token(data)->expert(model) resharding is where XLA inserts the
all-to-all — that collective is a first-class §Roofline term.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dtype, dense_init, init_mlp, apply_mlp
from repro.sharding import shard_act


def init_moe(key, cfg: ModelConfig):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    pd = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": {"w": dense_init(ks[0], (d, E), dtype=jnp.float32)},
        "experts": {
            "wi": dense_init(ks[1], (E, d, ff), in_axis=-2, dtype=pd),
            "wg": dense_init(ks[2], (E, d, ff), in_axis=-2, dtype=pd),
            "wo": dense_init(ks[3], (E, ff, d), in_axis=-2, dtype=pd),
        },
    }
    if cfg.n_shared_experts > 0:
        p["shared_expert"] = init_mlp(ks[4], cfg)
    return p


def expert_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    cap = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    return max(8, ((cap + 7) // 8) * 8)   # 8-aligned for TPU tiling


def _route(p, xt, cfg: ModelConfig):
    """Router in fp32: returns (gate (T,K), expert_idx (T,K), aux)."""
    E, K = cfg.n_experts, cfg.top_k
    logits = xt.astype(jnp.float32) @ p["router"]["w"]     # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, K)             # (T, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # aux loss (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_idx, E).sum(axis=1), axis=0)
    aux = E * jnp.sum(me * ce) * cfg.router_aux_weight
    return gate, expert_idx, aux


def _dispatch_compute_combine(p, xt, gate, expert_idx, C, cfg: ModelConfig):
    """Sort-based dispatch -> per-expert dense matmuls -> combine.
    xt: (T, d). Returns (T, d)."""
    E, K = cfg.n_experts, cfg.top_k
    T, d = xt.shape
    dt = xt.dtype

    flat_expert = expert_idx.reshape(-1)                    # (T*K,)
    sort_idx = jnp.argsort(flat_expert)                     # stable
    sorted_expert = flat_expert[sort_idx]
    counts = jnp.bincount(flat_expert, length=E)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(T * K) - offsets[sorted_expert]       # rank within expert
    keep = rank < C

    token_of = sort_idx // K                                # source token per slot
    buf = jnp.zeros((E, C, d), dt)
    scat_e = jnp.where(keep, sorted_expert, 0)
    scat_c = jnp.where(keep, rank, 0).astype(jnp.int32)
    src = jnp.where(keep[:, None], xt[token_of], 0).astype(dt)
    buf = buf.at[scat_e, scat_c].add(src)                   # (E, C, d)
    buf = shard_act(buf, "act_experts", None, None)

    wi = p["experts"]["wi"].astype(dt)
    wg = p["experts"]["wg"].astype(dt)
    wo = p["experts"]["wo"].astype(dt)
    h = jnp.einsum("ecd,edf->ecf", buf, wi)
    g = jnp.einsum("ecd,edf->ecf", buf, wg)
    h = jax.nn.silu(g) * h
    out_buf = jnp.einsum("ecf,efd->ecd", h, wo)             # (E, C, d)
    out_buf = shard_act(out_buf, "act_experts", None, None)

    gathered = out_buf[scat_e, scat_c]                      # (T*K, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    unsorted = jnp.zeros((T * K, d), dt).at[sort_idx].set(gathered)
    per_k = unsorted.reshape(T, K, d)
    return jnp.einsum("tkd,tk->td", per_k, gate.astype(dt))


def apply_moe(p, x, cfg: ModelConfig):
    """x: (B, S, d) -> (B, S, d), aux load-balance loss.

    Baseline path: one GLOBAL sort/scatter over all T tokens — simple,
    but the token->expert resharding crosses the whole mesh (the
    collective-bound term in §Roofline for the MoE giants).

    Grouped path (cfg.moe_grouped_dispatch — beyond-paper §Perf
    optimization): tokens are dispatched within ``moe_groups`` groups
    aligned with the data-parallel shards, so argsort/scatter/gather
    stay shard-local and only the (G, E, C/G, d) buffer crosses the
    data->model boundary for expert compute — the hierarchical
    dispatch used by production MoE frameworks, adapted to XLA SPMD.
    """
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    gate, expert_idx, aux = _route(p, xt, cfg)

    C = expert_capacity(cfg, T)
    if cfg.moe_grouped_dispatch and T % cfg.moe_groups == 0 and \
            T >= cfg.moe_groups * cfg.n_experts:
        G = cfg.moe_groups
        Cg = max(8, ((C // G + 7) // 8) * 8)
        xg = xt.reshape(G, T // G, d)
        gg = gate.reshape(G, T // G, -1)
        eg = expert_idx.reshape(G, T // G, -1)
        xg = shard_act(xg, "batch", None, None)   # groups ride the data axis
        y = jax.vmap(
            lambda xi, gi, ei: _dispatch_compute_combine(p, xi, gi, ei, Cg, cfg)
        )(xg, gg, eg)
        y = y.reshape(T, d)
    else:
        y = _dispatch_compute_combine(p, xt, gate, expert_idx, C, cfg)

    if "shared_expert" in p:
        y = y + apply_mlp(p["shared_expert"], xt, cfg)
    return y.reshape(B, S, d), aux
