"""GQA attention: training/prefill (causal, optional sliding window),
cross-attention (enc-dec), and single-token decode against a KV cache.

The jnp path here is the lowering path for the TPU dry-run; the Pallas
flash kernels in ``repro.kernels`` implement the same math for the
real-TPU hot path (cfg.use_pallas) and are validated against
``repro.kernels.ref`` in interpret mode.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dtype, apply_rope, dense_init
from repro.sharding import shard_act


def init_attention(key, cfg: ModelConfig, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pd = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H * hd), dtype=pd),
        "wk": dense_init(ks[1], (d, KV * hd), dtype=pd),
        "wv": dense_init(ks[2], (d, KV * hd), dtype=pd),
        "wo": dense_init(ks[3], (H * hd, d), dtype=pd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), pd)
        p["bk"] = jnp.zeros((KV * hd,), pd)
        p["bv"] = jnp.zeros((KV * hd,), pd)
        p["bo"] = jnp.zeros((d,), pd)
    return p


def _project_qkv(p, x, x_kv, cfg: ModelConfig):
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    q = x @ p["wq"].astype(dt)
    k = x_kv @ p["wk"].astype(dt)
    v = x_kv @ p["wv"].astype(dt)
    if "bq" in p:
        q, k, v = q + p["bq"].astype(dt), k + p["bk"].astype(dt), v + p["bv"].astype(dt)
    q = q.reshape(B, -1, H, hd)
    k = k.reshape(B, -1, KV, hd)
    v = v.reshape(B, -1, KV, hd)
    return q, k, v


def _gqa_scores(q, k):
    """q: (B,S,H,hd), k: (B,T,KV,hd) -> scores (B,KV,G,S,T), G=H/KV."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    return jnp.einsum("bskgd,btkd->bkgst", qg, k) / jnp.sqrt(hd).astype(q.dtype)


def _gqa_out(probs, v, B, S, H, hd):
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, H, hd)


# q-chunked attention kicks in above this q-length: memory goes from
# O(S^2) score buffers to O(chunk * S) — required to lower prefill_32k
# without a 17GB transient per chip. (The Pallas flash kernel is the
# real-TPU path; this is its XLA-lowerable twin.)
CHUNK_THRESHOLD = 8192
CHUNK_Q = 1024


def _attention_math(q, k, v, positions, kv_positions, causal, sliding_window,
                    B, S, H, hd):
    scores = _gqa_scores(q, k).astype(jnp.float32)       # (B,KV,G,S,T)
    if causal or sliding_window > 0:
        qpos = positions[:, None, None, :, None]
        kpos = kv_positions[:, None, None, None, :]
        mask = kpos <= qpos if causal else jnp.ones((), bool)
        if sliding_window > 0:
            mask = mask & (kpos > qpos - sliding_window)
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return _gqa_out(probs, v, B, S, H, hd)


def attend_full(p, x, cfg: ModelConfig, *, positions=None, causal=True,
                x_kv=None, kv_positions=None, sliding_window: int = 0):
    """Training / prefill attention. x: (B, S, d)."""
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    x_kv = x if x_kv is None else x_kv
    T = x_kv.shape[1]
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if kv_positions is None:
        kv_positions = positions if x_kv is x else jnp.arange(T)[None, :]

    q, k, v = _project_qkv(p, x, x_kv, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, kv_positions, cfg.rope_theta)
    q = shard_act(q, "batch", "seq", "act_heads", None)
    k = shard_act(k, "batch", "seq", "act_heads", None)
    v = shard_act(v, "batch", "seq", "act_heads", None)

    chunk_q = cfg.attn_chunk_q or CHUNK_Q
    if S > CHUNK_THRESHOLD and S % chunk_q == 0:
        nq = S // chunk_q
        qc = jnp.moveaxis(q.reshape(B, nq, chunk_q, H, hd), 1, 0)
        pos_b = jnp.broadcast_to(positions, (B, S))
        pc = jnp.moveaxis(pos_b.reshape(B, nq, chunk_q), 1, 0)

        def one_chunk(args):
            qi, pi = args
            return _attention_math(qi, k, v, pi, kv_positions, causal,
                                   sliding_window, B, chunk_q, H, hd)

        out = jax.lax.map(one_chunk, (qc, pc))          # (nq,B,cq,H,hd)
        out = jnp.moveaxis(out, 0, 1).reshape(B, S, H, hd)
    else:
        out = _attention_math(q, k, v, positions, kv_positions, causal,
                              sliding_window, B, S, H, hd)
    out = shard_act(out, "batch", "seq", "act_heads", None)
    out = out.reshape(B, S, H * hd) @ p["wo"].astype(x.dtype)
    if "bo" in p:
        out = out + p["bo"].astype(x.dtype)
    return out


# ---------------------------------------------------------------------------
# decode (single new token against a cache)

def cache_dtype(cfg: ModelConfig):
    """KV-cache storage dtype; cfg.cache_dtype="float8_e4m3fn" enables
    quantized-cache serving (a beyond-paper §Perf optimization)."""
    if cfg.cache_dtype:
        return jnp.dtype(cfg.cache_dtype)
    return _dtype(cfg.dtype)


def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int, d_model=None):
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    dt = cache_dtype(cfg)
    if cfg.cache_ring and cfg.sliding_window:
        # O(window) ring buffer: slots are overwritten at pos % W, which
        # by construction keeps exactly the last W positions — the
        # sliding-window mask becomes free
        max_seq = min(max_seq, cfg.sliding_window)
    return {
        "k": jnp.zeros((batch, max_seq, KV, hd), dt),
        "v": jnp.zeros((batch, max_seq, KV, hd), dt),
    }


def _write_cache_rows(cache, new, write_pos):
    """Per-row cache write: cache (B,Smax,KV,hd), new (B,1,KV,hd),
    write_pos (B,) int32 — each batch row writes at its own position
    (the continuous-batching layout where slots decode out of step)."""
    def one(c, n, p):
        return jax.lax.dynamic_update_slice(c, n.astype(c.dtype), (p, 0, 0))
    return jax.vmap(one)(cache, new, write_pos)


def attend_decode(p, x, cache, pos, cfg: ModelConfig, *,
                  sliding_window: int = 0, update_cache: bool = True):
    """One-token decode. x: (B, 1, d); cache k/v: (B, Smax, KV, hd);
    pos: () int32 — current position (tokens 0..pos-1 are valid) — or
    (B,) int32, one position per row (the continuous-batching serving
    layout: every cache slot sits at its own sequence position).

    Returns (out (B,1,d), new_cache). The full-cache masked read is the
    baseline lowering; ``cfg.use_pallas`` routes the cache read through
    the ``flash_decode`` Pallas kernel (same math, online softmax over
    sequence tiles — parity pinned in tests/test_kernels.py and inside
    full generations in tests/test_serve.py).
    """
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    Smax = cache["k"].shape[1]
    ring = bool(cfg.cache_ring and cfg.sliding_window and
                cfg.sliding_window >= Smax)
    pos = jnp.asarray(pos, jnp.int32)
    per_row = pos.ndim == 1
    q, k_new, v_new = _project_qkv(p, x, x, cfg)
    posb = pos[:, None] if per_row else jnp.full((B, 1), pos, jnp.int32)
    q = apply_rope(q, posb, cfg.rope_theta)
    k_new = apply_rope(k_new, posb, cfg.rope_theta)

    if update_cache:
        write_pos = (pos % Smax) if ring else pos
        if per_row:
            k = _write_cache_rows(cache["k"], k_new, write_pos)
            v = _write_cache_rows(cache["v"], v_new, write_pos)
        else:
            k = jax.lax.dynamic_update_slice(
                cache["k"], k_new.astype(cache["k"].dtype), (0, write_pos, 0, 0))
            v = jax.lax.dynamic_update_slice(
                cache["v"], v_new.astype(cache["v"].dtype), (0, write_pos, 0, 0))
    else:
        k, v = cache["k"], cache["v"]
    k = shard_act(k, "batch", "cache_seq", "act_heads", None)
    v = shard_act(v, "batch", "cache_seq", "act_heads", None)

    if cfg.use_pallas:
        # the decode hot path: stream the cache once through the Pallas
        # flash-decode kernel (ring caches: the window mask is already
        # structural — slots hold exactly the last Smax positions)
        from repro.kernels import ops
        o = ops.flash_decode(jnp.swapaxes(q, 1, 2).astype(x.dtype),
                             jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
                             pos, window=0 if ring else sliding_window)
        out = jnp.swapaxes(o, 1, 2)                      # (B,1,H,hd)
    else:
        # quantized caches: upcast at the matmul (XLA fuses the convert)
        k_c = k.astype(x.dtype) if k.dtype != x.dtype else k
        v_c = v.astype(x.dtype) if v.dtype != x.dtype else v
        scores = _gqa_scores(q, k_c).astype(jnp.float32)  # (B,KV,G,1,Smax)
        kpos = jnp.arange(Smax)[None, :]
        # ring: slots hold exactly the last Smax positions; only warmup
        # slots (never written) are masked — the window mask is structural
        valid = kpos <= posb                              # (B, Smax)
        if sliding_window > 0 and not ring:
            valid = valid & (kpos > posb - sliding_window)
        scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = _gqa_out(probs, v_c, B, 1, H, hd)
    out = out.reshape(B, 1, H * hd) @ p["wo"].astype(x.dtype)
    if "bo" in p:
        out = out + p["bo"].astype(x.dtype)
    return out, {"k": k, "v": v}


def attend_prefill(p, x, cache, pos0, cfg: ModelConfig, *,
                   sliding_window: int = 0):
    """Chunked-prefill attention: one forward over a prompt chunk with
    KV-cache writeback — the program that replaces per-token prefill
    loops. x: (B, C, d) holds positions ``pos0 .. pos0+C-1`` (lock-step
    across the batch — the serve engine pads prompts to the bucket
    ceiling); k/v for the chunk are written into the cache at ``pos0``
    and q attends to the full cache under the causal (+ window) mask, so
    earlier chunks' entries participate. Returns (out (B,C,d), cache).

    Rows whose real prompt is shorter than the chunk get garbage tail
    entries in the cache — harmless by construction: decode overwrites
    position t before any query can attend to it (the serve engine
    starts each row's decode at its own prompt length).
    """
    B, C, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    Smax = cache["k"].shape[1]
    q, k_new, v_new = _project_qkv(p, x, x, cfg)
    positions = pos0 + jnp.arange(C)[None, :]            # (1, C)
    q = apply_rope(q, positions, cfg.rope_theta)
    k_new = apply_rope(k_new, positions, cfg.rope_theta)
    k = jax.lax.dynamic_update_slice(
        cache["k"], k_new.astype(cache["k"].dtype), (0, pos0, 0, 0))
    v = jax.lax.dynamic_update_slice(
        cache["v"], v_new.astype(cache["v"].dtype), (0, pos0, 0, 0))
    k_c = k.astype(x.dtype) if k.dtype != x.dtype else k
    v_c = v.astype(x.dtype) if v.dtype != x.dtype else v
    kv_positions = jnp.arange(Smax)[None, :]
    out = _attention_math(q, k_c, v_c, positions, kv_positions, True,
                          sliding_window, B, C, H, hd)
    out = out.reshape(B, C, H * hd) @ p["wo"].astype(x.dtype)
    if "bo" in p:
        out = out + p["bo"].astype(x.dtype)
    return out, {"k": k, "v": v}
