"""Unified model interface used by the trainer, the swarm layer, the
serving path and the dry-run launcher.

``build_model(cfg)`` returns a :class:`Model` whose members are pure
functions over parameter pytrees — the BSO-SL core only ever touches
params through this interface, which is what makes the paper's
model-agnostic claim (RQ2) structural rather than incidental.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import cnn as cnn_lib
from repro.models import transformer as tf_lib
from repro.models.layers import _dtype


def cross_entropy(logits, labels):
    """Mean token-level CE in fp32; labels < 0 are masked out.

    The label logit is extracted with a fused iota-compare-select rather
    than take_along_axis: a gather on vocab-sharded logits forces an
    all-gather under SPMD, while the select partitions cleanly over the
    vocab shards (each contributes its local partial sum).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    mask = labels >= 0
    safe = jnp.where(mask, labels, 0)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    label_logit = jnp.sum(
        jnp.where(vocab_iota == safe[..., None], logits, 0.0), axis=-1)
    nll = lse - label_logit
    nll = jnp.where(mask, nll, 0.0)
    return nll.sum() / jnp.maximum(mask.sum(), 1)


def accuracy(logits, labels):
    mask = labels >= 0
    pred = jnp.argmax(logits, axis=-1)
    hit = jnp.where(mask, pred == labels, False)
    return hit.sum() / jnp.maximum(mask.sum(), 1)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[Any], Any]                       # key -> params
    forward: Callable[[Any, dict], tuple]            # (params, batch) -> (logits, aux)
    loss: Callable[[Any, dict], tuple]               # (params, batch) -> (loss, metrics)
    init_cache: Optional[Callable] = None            # (batch, max_seq) -> cache
    decode_step: Optional[Callable] = None           # (params, tok, cache, pos) -> (logits, cache)
    prefill: Optional[Callable] = None               # (params, toks, cache, pos0) -> (logits, cache)

    def param_count(self, params) -> int:
        return sum(x.size for x in jax.tree.leaves(params))


def _lm_loss(fwd):
    def loss(params, batch):
        logits, aux = fwd(params, batch)
        ce = cross_entropy(logits, batch["labels"])
        total = ce + aux
        return total, {"loss": total, "ce": ce, "aux": aux,
                       "acc": accuracy(logits, batch["labels"])}
    return loss


# cached on the (hashable, frozen) config: the constructor only closes
# over cfg, and returning the SAME instance makes downstream jit caches
# (notably the engine's one-program swarm_round, whose static
# EngineConfig embeds the model) hash equal across callers instead of
# recompiling per construction
@functools.cache
def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "cnn":
        def fwd(params, batch):
            return cnn_lib.apply_cnn(params, batch["images"], cfg), jnp.zeros((), jnp.float32)

        def loss(params, batch):
            logits, _ = fwd(params, batch)
            ce = cross_entropy(logits, batch["labels"])
            return ce, {"loss": ce, "ce": ce,
                        "acc": accuracy(logits, batch["labels"])}

        return Model(cfg, lambda key: cnn_lib.init_cnn(key, cfg), fwd, loss)

    if cfg.family == "encdec":
        def fwd(params, batch):
            return tf_lib.encdec_forward(params, batch, cfg)

        return Model(
            cfg,
            lambda key: tf_lib.init_encdec(key, cfg),
            fwd,
            _lm_loss(fwd),
            init_cache=lambda b, s: tf_lib.init_encdec_cache(cfg, b, s),
            decode_step=lambda p, t, c, pos: tf_lib.encdec_decode_step(p, t, c, pos, cfg),
        )

    # decoder-only families: dense / moe / ssm / hybrid / vlm
    def fwd(params, batch):
        return tf_lib.lm_forward(params, batch, cfg)

    return Model(
        cfg,
        lambda key: tf_lib.init_lm(key, cfg),
        fwd,
        _lm_loss(fwd),
        init_cache=lambda b, s: tf_lib.init_lm_cache(cfg, b, s),
        decode_step=lambda p, t, c, pos: tf_lib.lm_decode_step(p, t, c, pos, cfg),
        # chunked prefill (one forward + cache writeback) — attention
        # families only; the SSM recurrence cannot mask padded prompt
        # tails after the fact (repro.serve gates on this)
        prefill=(lambda p, t, c, pos0: tf_lib.lm_prefill(p, t, c, pos0, cfg))
        if cfg.family in ("dense", "moe") else None,
    )


# ---------------------------------------------------------------------------
# ShapeDtypeStruct input specs for the dry-run (no allocation)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Stand-in inputs for one (arch × input-shape) pair.

    train/prefill => a full batch for ``train_step``/forward;
    decode        => (tokens, pos) for ``serve_step`` (the cache spec is
    produced separately via ``cache_specs``).
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sd = jax.ShapeDtypeStruct
    act = _dtype(cfg.dtype)

    if cfg.family == "cnn":
        return {"images": sd((B, 32, 32, 3), jnp.float32), "labels": sd((B,), i32)}

    if shape.kind in ("train", "prefill"):
        if cfg.family == "encdec":
            S_dec = min(448, S)
            return {"audio_embed": sd((B, S, cfg.d_model), act),
                    "tokens": sd((B, S_dec), i32),
                    "labels": sd((B, S_dec), i32)}
        if cfg.family == "vlm":
            S_text = S - cfg.n_vision_tokens
            return {"vision_embed": sd((B, cfg.n_vision_tokens, cfg.d_model), act),
                    "tokens": sd((B, S_text), i32),
                    "labels": sd((B, S_text), i32)}
        return {"tokens": sd((B, S), i32), "labels": sd((B, S), i32)}

    # decode: one new token against a seq_len cache
    return {"tokens": sd((B, 1), i32), "pos": sd((), i32)}


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    """abstract cache pytree (ShapeDtypeStructs) for decode shapes."""
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init_cache(shape.global_batch, shape.seq_len))
