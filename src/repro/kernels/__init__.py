"""Pallas TPU kernels for the framework's compute hot-spots.

flash_attention — train/prefill attention (online softmax, GQA index maps)
flash_decode    — single-token decode against long KV caches
param_stats     — the paper's §III.B distribution summarisation reduction
                  (shifted accumulation; `param_stats_batched` serves the
                  whole client-stacked swarm on an (N, blocks) grid)
kmeans_assign   — the coordinator's nearest-centroid step (wired into the
                  jit'd Lloyd loop in core/kmeans via use_pallas=True)

Each kernel has a pure-jnp oracle in ref.py; ops.py exposes jit'd
wrappers that auto-select interpret mode off-TPU.
"""
from repro.kernels import ops, ref  # noqa: F401
