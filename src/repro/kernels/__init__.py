"""Pallas TPU kernels for the framework's compute hot-spots.

flash_attention — train/prefill attention (online softmax, GQA index maps)
flash_decode    — single-token decode against long KV caches
param_stats     — the paper's §III.B distribution summarisation reduction
kmeans_assign   — the coordinator's nearest-centroid step

Each kernel has a pure-jnp oracle in ref.py; ops.py exposes jit'd
wrappers that auto-select interpret mode off-TPU.
"""
from repro.kernels import ops, ref  # noqa: F401
