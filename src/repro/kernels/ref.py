"""Pure-jnp oracles for every Pallas kernel.

Tests sweep shapes/dtypes and assert_allclose kernel-vs-oracle; the
model code's jnp path is mathematically identical to these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_attention(q, k, v, *, causal=True, window=0, q_offset=0):
    """q: (B,H,Sq,D); k,v: (B,KV,Sk,D) with H % KV == 0.
    q_offset: global position of q row 0 (decode: pos)."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, Sq, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bkgsd,bktd->bkgst", qg, kf) / jnp.sqrt(D)
    rows = q_offset + jnp.arange(Sq)[:, None]
    cols = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = cols <= rows
    if window > 0:
        mask = mask & (cols > rows - window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, D).astype(q.dtype)


def ref_decode_attention(q, k, v, pos, *, window=0):
    """q: (B,H,1,D); k,v: (B,KV,S,D); pos: () or (B,) — keys 0..pos
    valid per row (vector pos = the continuous-batching layout)."""
    B, H, _, D = q.shape
    KV, S = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bktd->bkgt", qg, k.astype(jnp.float32)) / jnp.sqrt(D)
    cols = jnp.arange(S)[None, :]
    posb = jnp.broadcast_to(jnp.asarray(pos).reshape(-1), (B,))[:, None]
    mask = cols <= posb                                  # (B, S)
    if window > 0:
        mask = mask & (cols > posb - window)
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,bktd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, 1, D).astype(q.dtype)


def ref_param_stats(x):
    """(mean, var) of a flat tensor, fp32."""
    xf = x.astype(jnp.float32).reshape(-1)
    return jnp.mean(xf), jnp.var(xf)


def ref_param_stats_batched(x):
    """Per-client (mean, var) over trailing axes: x (N, ...) fp32."""
    flat = x.astype(jnp.float32).reshape(x.shape[0], -1)
    return jnp.mean(flat, axis=1), jnp.var(flat, axis=1)


def ref_kmeans_assign(X, C):
    """Nearest-centroid ids: X (N,F), C (K,F) -> (N,) int32."""
    x2 = jnp.sum(X.astype(jnp.float32) ** 2, axis=1, keepdims=True)
    c2 = jnp.sum(C.astype(jnp.float32) ** 2, axis=1)[None, :]
    d = x2 + c2 - 2.0 * X.astype(jnp.float32) @ C.astype(jnp.float32).T
    return jnp.argmin(d, axis=1).astype(jnp.int32)
