"""Flash-decode: single-token attention against a long KV cache.

The decode hot-spot is memory-bound (stream the whole cache once); the
kernel tiles the cache's sequence axis into VMEM blocks and keeps the
online-softmax state in scratch. Positions beyond ``pos`` (and outside
the sliding window) are masked per tile, so ring-buffer caches work
unchanged.

``pos`` may be a scalar (every row at the same position — the original
lock-step decode) or a per-row ``(B,)`` vector — the continuous-batching
serving path, where each cache slot sits at its own sequence position.
Cache lengths that are not a multiple of ``block_k`` are zero-padded up
to the next block boundary; the padded columns sit at ``cols > pos`` and
are masked by the causal mask, so the result is unchanged.

Grid: (B, H, n_k_blocks) — one q row per (batch, head), cache blocks
innermost/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale, window, block_k, n_k):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[b]
    q = q_ref[0, 0].astype(jnp.float32)               # (1, d)
    k = k_ref[0, 0].astype(jnp.float32)               # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (1, bk)
    cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    mask = cols <= pos
    if window > 0:
        mask = mask & (cols > pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev[0, 0], jnp.max(s))[None, None]
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)

    l_scr[...] = alpha * l_scr[...] + jnp.sum(p)[None, None]
    acc_scr[...] = acc_scr[...] * alpha + \
        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "block_k", "interpret"))
def flash_decode(q, k, v, pos, *, window=0, block_k=256, interpret=False):
    """q: (B,H,1,D); k,v: (B,KV,S,D); pos: () or (B,) int32.
    Returns (B,H,1,D)."""
    B, H, _, D = q.shape
    KV, S = k.shape[1], k.shape[2]
    G = H // KV
    block_k = min(block_k, S)
    if S % block_k:
        # ragged cache length: pad the seq axis to the next block
        # boundary. Pad columns have cols > pos (pos < S always) so the
        # causal mask zeroes their probability — bitwise no-op.
        pad = block_k - S % block_k
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        S = S + pad
    n_k = S // block_k
    grid = (B, H, n_k)

    kernel = functools.partial(_decode_kernel, scale=1.0 / (D ** 0.5),
                               window=window, block_k=block_k, n_k=n_k)
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, 1, D), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ki, g=G: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ki, g=G: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, q, k, v)
