"""param_stats: streaming sum / sum-of-squares over a parameter tensor.

This is the paper's §III.B distribution-summarisation step as a TPU
kernel: a pure memory-bound reduction over up to billions of elements,
tiled (rows, 128) into VMEM, accumulating partial sums across the
sequential grid. The wrapper turns (sum, sumsq, n) into (mean, var).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _stats_kernel(x_ref, out_ref, *, n_blocks):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)
    out_ref[0, 0] += jnp.sum(x)
    out_ref[0, 1] += jnp.sum(x * x)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def param_stats(x, *, block_rows=256, interpret=False):
    """Returns (mean, var) fp32 of any-shape floating tensor ``x``.

    Zero-padding is harmless to sum/sumsq; the true element count
    normalises.
    """
    n = x.size
    flat = x.reshape(-1).astype(jnp.float32)
    per_block = block_rows * LANES
    n_blocks = max(1, -(-n // per_block))
    padded = n_blocks * per_block
    flat = jnp.pad(flat, (0, padded - n))
    tiles = flat.reshape(n_blocks * block_rows, LANES)

    kernel = functools.partial(_stats_kernel, n_blocks=n_blocks)
    out = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 2), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 2), jnp.float32),
        interpret=interpret,
    )(tiles)
    s, ss = out[0, 0], out[0, 1]
    mean = s / n
    var = jnp.maximum(ss / n - mean * mean, 0.0)
    return mean, var
