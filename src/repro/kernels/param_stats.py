"""param_stats: streaming moment reduction over parameter tensors.

This is the paper's §III.B distribution-summarisation step as a TPU
kernel: a pure memory-bound reduction over up to billions of elements,
tiled (rows, 128) into VMEM, accumulating partial sums across the
sequential grid.

Two numerics/throughput properties beyond the naive version:

* **Shifted accumulation.** The kernel accumulates sum(x - shift) and
  sum((x - shift)^2) with shift = the mean of the first block, so the
  wrapper's ``E[d^2] - E[d]^2`` does not catastrophically cancel when
  ``mean^2 >> var`` (the naive ``ss/n - mean^2`` loses half the fp32
  mantissa on large-mean tensors).

* **Client-batched entry point.** ``param_stats_batched`` reduces a
  client-stacked ``(N, ...)`` tensor on an ``(N, n_blocks)`` grid — the
  whole swarm's per-tensor stats in ONE device program instead of N
  host dispatches (the coordinator hot path of a BSO-SL round).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128

# out row layout: [sum(x-shift), sum((x-shift)^2), shift, unused]
_OUT_W = 4


def _stats_kernel(x_ref, out_ref, *, n_blocks, n_tail, inv_first):
    i = pl.program_id(1)
    x = x_ref[0].astype(jnp.float32)            # (block_rows, LANES)
    rows, lanes = x.shape

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        # shift = mean of the first block's real elements: zero padding
        # never perturbs the sum and inv_first normalises by the true
        # valid count, so the shift lands on the data's magnitude.
        out_ref[0, 2] = jnp.sum(x) * inv_first

    # Mask the tail padding: a padded zero would contribute (0 - shift)
    # to the shifted moments, and correcting that analytically in the
    # wrapper re-introduces the very cancellation the shift removes
    # (n_pad * shift^2 can dwarf the real sum of squares). Only the
    # final block carries padding, so mask by block-local index — a
    # global element index would overflow int32 for >=2^31-element
    # tensors, which this module explicitly serves.
    idx_local = (jax.lax.broadcasted_iota(jnp.int32, x.shape, 0) * lanes
                 + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1))
    valid = (i < n_blocks - 1) | (idx_local < n_tail)
    shift = out_ref[0, 2]
    d = jnp.where(valid, x - shift, 0.0)
    out_ref[0, 0] += jnp.sum(d)
    out_ref[0, 1] += jnp.sum(d * d)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def param_stats_batched(x, *, block_rows=256, interpret=False):
    """Per-client (mean, var) over the trailing axes of ``x`` (N, ...).

    Returns two fp32 vectors of shape (N,). One pallas_call with grid
    (N, n_blocks): the block axis is innermost, so each client's
    accumulator row is revisited sequentially (the standard revisited-
    output reduction pattern).
    """
    N = x.shape[0]
    n = x.size // N
    if n == 0:
        # empty tensor: (nan, nan) like jnp.mean/var, never a trace crash
        nan = jnp.full((N,), jnp.nan, jnp.float32)
        return nan, nan
    # keep the input dtype end-to-end: the kernel casts per block in
    # VMEM, so a wrapper-level astype would double HBM traffic for the
    # memory-bound bf16 case
    flat = x.reshape(N, -1)
    per_block = block_rows * LANES
    n_blocks = max(1, -(-n // per_block))
    padded = n_blocks * per_block
    flat = jnp.pad(flat, ((0, 0), (0, padded - n)))
    tiles = flat.reshape(N, n_blocks * block_rows, LANES)

    kernel = functools.partial(_stats_kernel, n_blocks=n_blocks,
                               n_tail=n - (n_blocks - 1) * per_block,
                               inv_first=1.0 / min(n, per_block))
    out = pl.pallas_call(
        kernel,
        grid=(N, n_blocks),
        in_specs=[pl.BlockSpec((1, block_rows, LANES), lambda b, i: (b, i, 0))],
        out_specs=pl.BlockSpec((1, _OUT_W), lambda b, i: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((N, _OUT_W), jnp.float32),
        interpret=interpret,
    )(tiles)

    sd, ssd, shift = out[:, 0], out[:, 1], out[:, 2]
    mean = shift + sd / n
    var = jnp.maximum(ssd / n - (sd / n) ** 2, 0.0)
    return mean, var


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def param_stats(x, *, block_rows=256, interpret=False):
    """Returns (mean, var) fp32 of any-shape floating tensor ``x``."""
    m, v = param_stats_batched(x.reshape((1,) + x.shape),
                               block_rows=block_rows, interpret=interpret)
    return m[0], v[0]
