"""jit'd public wrappers around the Pallas kernels.

On the CPU stand-in backend the kernels run in interpret mode (the
kernel body executed in Python — correctness path); on a real TPU they
compile to Mosaic. ``auto_interpret()`` picks per backend so model code
can call these unconditionally when cfg.use_pallas is set.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash_attention
from repro.kernels.flash_decode import flash_decode as _flash_decode
from repro.kernels.kmeans_assign import kmeans_assign as _kmeans_assign
from repro.kernels.param_stats import param_stats as _param_stats
from repro.kernels.param_stats import param_stats_batched as _param_stats_batched


def auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, q_offset=0, interpret=None):
    if interpret is None:
        interpret = auto_interpret()
    return _flash_attention(q, k, v, causal=causal, window=window,
                            block_q=block_q, block_k=block_k,
                            q_offset=q_offset, interpret=interpret)


def flash_attention_bsh(q, k, v, **kw):
    """(B,S,H,D)-layout convenience used by the model code."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention(qt, kt, vt, **kw)
    return jnp.swapaxes(out, 1, 2)


def flash_decode(q, k, v, pos, *, window=0, block_k=256, interpret=None):
    if interpret is None:
        interpret = auto_interpret()
    return _flash_decode(q, k, v, pos, window=window, block_k=block_k,
                         interpret=interpret)


def param_stats(x, *, block_rows=256, interpret=None):
    if interpret is None:
        interpret = auto_interpret()
    return _param_stats(x, block_rows=block_rows, interpret=interpret)


def param_stats_batched(x, *, block_rows=256, interpret=None):
    """Per-client (mean, var) of a client-stacked (N, ...) tensor in one
    device program — the swarm-wide §III.B reduction."""
    if interpret is None:
        interpret = auto_interpret()
    return _param_stats_batched(x, block_rows=block_rows, interpret=interpret)


def kmeans_assign(X, C, *, block_n=128, interpret=None):
    if interpret is None:
        interpret = auto_interpret()
    return _kmeans_assign(X, C, block_n=block_n, interpret=interpret)
