"""kmeans_assign: nearest-centroid assignment for the coordinator.

Tiles the client-feature matrix (block_n, F) against the full centroid
block (K, F) in VMEM — one distance matmul + argmin per tile. Feature
and centroid counts are padded to TPU lane multiples by the wrapper
(padded features are zero in both operands; padded centroids carry +inf
bias so they never win the argmin).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _assign_kernel(x_ref, c_ref, bias_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)                 # (bn, F)
    c = c_ref[...].astype(jnp.float32)                 # (K, F)
    bias = bias_ref[...].astype(jnp.float32)           # (1, K): 0 or +inf
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    c2 = jnp.sum(c * c, axis=1)[None, :]
    d = x2 + c2 - 2.0 * jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())))
    d = d + bias
    o_ref[...] = jnp.argmin(d, axis=1).astype(jnp.int32)[:, None]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign(X, C, *, block_n=128, interpret=False):
    """X: (N,F) clients; C: (K,F) centroids -> (N,) int32 assignments."""
    N, F = X.shape
    K = C.shape[0]
    Fp = -(-F // LANES) * LANES
    Kp = max(8, -(-K // 8) * 8)
    Np = -(-N // block_n) * block_n

    Xp = jnp.zeros((Np, Fp), jnp.float32).at[:N, :F].set(X.astype(jnp.float32))
    Cp = jnp.zeros((Kp, Fp), jnp.float32).at[:K, :F].set(C.astype(jnp.float32))
    bias = jnp.where(jnp.arange(Kp) < K, 0.0, jnp.inf)[None, :]

    out = pl.pallas_call(
        _assign_kernel,
        grid=(Np // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, Fp), lambda i: (i, 0)),
            pl.BlockSpec((Kp, Fp), lambda i: (0, 0)),
            pl.BlockSpec((1, Kp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, 1), jnp.int32),
        interpret=interpret,
    )(Xp, Cp, bias)
    return out[:N, 0]
