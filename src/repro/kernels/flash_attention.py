"""Flash attention (forward) as a Pallas TPU kernel.

TPU-native adaptation of the FlashAttention blocking: q/k/v tiles live
in VMEM via BlockSpec, the MXU does (block_q, d) x (d, block_k)
matmuls, and the online-softmax running (m, l, acc) state sits in VMEM
scratch. GQA is expressed in the *index map* — the kv-head block index
is ``h // group`` — so grouped KV heads are never materialised H times
(bandwidth saving vs. the repeat-kv GPU idiom).

Grid: (B, H, n_q_blocks, n_k_blocks), k-blocks innermost (sequential on
TPU), accumulating into scratch; the causal/sliding-window mask is
applied per-tile from global row/col indices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, causal, window, block_q, block_k, n_k, q_offset):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)              # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bk)

    rows = q_offset + qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask = mask & (cols <= rows)
    if window > 0:
        mask = mask & (cols > rows - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                               # (bq, 1)
    m_new = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=1))[:, None]
    alpha = jnp.exp(m_prev - m_new)                   # (bq, 1)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)      # (bq, bk)

    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1)[:, None]
    acc_scr[...] = acc_scr[...] * alpha + \
        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "q_offset",
                     "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, q_offset=0, interpret=False):
    """q: (B,H,Sq,D); k,v: (B,KV,Sk,D). Returns (B,H,Sq,D)."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    if Sq % block_q or Sk % block_k:
        raise ValueError(f"seq ({Sq},{Sk}) must divide blocks ({block_q},{block_k})")
    n_q, n_k = Sq // block_q, Sk // block_k
    grid = (B, H, n_q, n_k)

    kernel = functools.partial(
        _flash_kernel, scale=1.0 / (D ** 0.5), causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k=n_k, q_offset=q_offset)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki, g=G: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki, g=G: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
