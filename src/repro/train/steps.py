"""train_step / eval_step / serve_step factories.

``make_train_step`` supports gradient accumulation over microbatches
(lax.scan) — the activation-memory lever for the ≥100B dry-runs — and
is the function the dry-run lowers with pjit in/out shardings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim.optimizers import Optimizer
from repro.utils.tree import tree_zeros_like


def make_train_step(model: Model, opt: Optimizer, *, microbatches: int = 0):
    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    if microbatches and microbatches > 1:
        def train_step(params, opt_state, batch, lr):
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])

            mb = jax.tree.map(split, batch)

            def body(acc, mbatch):
                g_acc, m_acc = acc
                (loss, metrics), grads = grad_fn(params, mbatch)
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                m_acc = jax.tree.map(jnp.add, m_acc, metrics)
                return (g_acc, m_acc), None

            g0 = tree_zeros_like(params)
            # metrics accumulator with the right structure (no compute)
            metrics_shape = jax.eval_shape(
                lambda p, b: loss_fn(p, b)[1], params, jax.tree.map(lambda x: x[0], mb))
            m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), metrics_shape)
            (grads, msum), _ = jax.lax.scan(body, (g0, m0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m / microbatches, msum)
            new_params, new_opt = opt.update(grads, opt_state, params, lr)
            return new_params, new_opt, metrics
    else:
        def train_step(params, opt_state, batch, lr):
            (loss, metrics), grads = grad_fn(params, batch)
            new_params, new_opt = opt.update(grads, opt_state, params, lr)
            return new_params, new_opt, metrics

    return train_step


def make_eval_step(model: Model):
    def eval_step(params, batch):
        _, metrics = model.loss(params, batch)
        return metrics
    return eval_step


def make_serve_step(model: Model, *, sample: str = "greedy"):
    """One decode iteration: logits for the new token + updated cache +
    the greedy next token. This is what decode_32k / long_500k lower.
    ``pos`` may be () for lock-step decode or (B,) for per-row positions
    (the repro.serve continuous-batching engine)."""
    def serve_step(params, tokens, cache, pos):
        logits, new_cache = model.decode_step(params, tokens, cache, pos)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, logits, new_cache
    return serve_step


def make_prefill_step(model: Model):
    """Chunked prefill: one forward over a (B, C) prompt chunk with
    KV-cache writeback (``model.prefill``). Returns the full (B, C, V)
    logits + the updated cache; the serve engine gathers each request's
    last-real-token row. Attention families only (``model.prefill`` is
    None for ssm/hybrid/encdec — those serve via the per-token path)."""
    if model.prefill is None:
        raise ValueError(f"{model.cfg.arch_id} ({model.cfg.family}) has no "
                         "chunked-prefill path")

    def prefill_step(params, tokens, cache, pos0):
        return model.prefill(params, tokens, cache, pos0)
    return prefill_step
