from repro.train.steps import make_eval_step, make_serve_step, make_train_step  # noqa: F401
