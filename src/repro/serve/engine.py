"""The continuous-batching inference engine.

``ServeEngine`` turns a single parameter tree (e.g. a fleet-driver
checkpoint reduced by ``repro.serve.api.load_checkpoint``) into a
request-level server with the training engine's static-shape
discipline:

* a fixed pool of KV-cache slots, partitioned into size buckets
  (``scheduler.BucketSpec``) — per bucket ONE compiled **prefill**
  program (chunked forward + cache writeback, replacing the per-token
  teacher-forcing loop) and ONE compiled **decode** program (per-slot
  positions; ``cfg.use_pallas`` routes the cache read through the
  ``flash_decode`` Pallas kernel);
* requests are admitted into free slots mid-flight — a slot finishing
  its generation frees up while its neighbours keep decoding (the
  decode program always runs the full bucket batch; inactive rows
  compute ignored garbage — the price of zero retraces);
* every per-step device→host pull is one ``(batch,)`` token vector.

``ImageClassifier`` is the stateless analogue for the paper's CNN
classifiers: per-batch-bucket compiled scoring programs over padded
image batches.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.serve.scheduler import BucketSpec, Request, SlotScheduler

SERVE_FAMILIES = ("dense", "moe")


# ------------------------------------------------------------------ results


@dataclass
class ServeResult:
    rid: int
    tokens: List[int]
    prompt_len: int
    bucket: str
    t_submit: float
    t_admit: float
    t_first: float
    t_done: float

    @property
    def latency(self) -> float:
        return self.t_done - self.t_submit

    @property
    def ttft(self) -> float:
        """Time to first token (queue wait + prefill)."""
        return self.t_first - self.t_submit


# ------------------------------------------------------------- cache merge


def _merge_slots(old, new, admit):
    """Per-slot cache select: admitted slots take the freshly prefilled
    cache, running slots keep theirs. KV leaves are (B, S, KV, hd) —
    batch-leading — or (n_periods, B, S, KV, hd) under scanned layers
    (batch second)."""
    def m(o, n):
        ax = 0 if n.ndim <= 4 else 1
        shape = [1] * n.ndim
        shape[ax] = -1
        return jnp.where(admit.reshape(shape), n, o)
    return jax.tree.map(m, old, new)


# ------------------------------------------------------------------ engine


class _BucketState:
    """Host-side mirror of one bucket's device pool."""

    def __init__(self, model: Model, spec: BucketSpec):
        self.spec = spec
        self.cache = model.init_cache(spec.batch, spec.seq)
        self.pos = np.zeros(spec.batch, np.int32)
        self.last_tok = np.zeros(spec.batch, np.int32)
        self.active = np.zeros(spec.batch, bool)
        self.gen: List[List[int]] = [[] for _ in range(spec.batch)]
        self.req: List[Optional[Request]] = [None] * spec.batch


class ServeEngine:
    """Continuous-batching LM server over a fixed slot pool.

    Parameters
    ----------
    model, params : the served model (family ``dense``/``moe`` — the
        families with a chunked-prefill path) and its single parameter
        tree.
    buckets : the ``BucketSpec`` pool layout
        (``scheduler.default_bucket_layout`` if omitted and ``max_seq``
        given).
    prefill_chunk : split each bucket's prefill forward into chunks of
        this many positions (0 = one chunk of the full bucket ceiling).
        Chunks ride the SAME compiled program — the loop is unrolled at
        trace time, so the per-bucket program budget is unchanged.
    """

    def __init__(self, model: Model, params, buckets: Sequence[BucketSpec],
                 *, prefill_chunk: int = 0, clock=time.perf_counter):
        cfg = model.cfg
        if cfg.family not in SERVE_FAMILIES or model.prefill is None:
            raise ValueError(
                f"ServeEngine serves attention-backed LMs {SERVE_FAMILIES}; "
                f"got family '{cfg.family}' (ssm/hybrid/encdec serve via "
                "the per-token repro.launch.serve path)")
        ring = bool(cfg.cache_ring and cfg.sliding_window)
        if ring:
            # ring caches clamp the slot axis to the window; prefill
            # writes [0, prompt_ceiling) contiguously, so prompts must
            # fit the ring (generation may still wrap past it)
            buckets = tuple(
                BucketSpec(b.batch, b.seq,
                           prompt_ceiling=min(b.seq, cfg.sliding_window))
                for b in buckets)
        self.model = model
        self.cfg = cfg
        self.params = params
        self.prefill_chunk = prefill_chunk
        self.clock = clock
        self.scheduler = SlotScheduler(buckets)
        self.state = [_BucketState(model, b) for b in self.scheduler.buckets]
        self.results: Dict[int, ServeResult] = {}
        self._prefill_fns = [self._make_prefill(b)
                             for b in self.scheduler.buckets]
        self._decode_fns = [self._make_decode()
                            for _ in self.scheduler.buckets]
        self.n_prefill_calls = 0
        self.n_decode_calls = 0

    # -- compiled programs ----------------------------------------------

    def _prefill_width(self, spec: BucketSpec) -> int:
        return spec.prompt_ceiling

    def _make_prefill(self, spec: BucketSpec):
        P = self._prefill_width(spec)
        C = self.prefill_chunk if (0 < self.prefill_chunk < P
                                   and P % self.prefill_chunk == 0) else P
        model = self.model

        def fn(params, tokens, cache, admit, last_idx):
            # chunked forward + cache writeback; the chunk loop unrolls
            # at trace time into the ONE per-bucket prefill program
            tok = jnp.zeros((spec.batch,), jnp.int32)
            new_cache = cache
            for ci in range(P // C):
                logits, new_cache = model.prefill(
                    params, tokens[:, ci * C:(ci + 1) * C], new_cache,
                    jnp.int32(ci * C))
                rel = last_idx - ci * C
                in_chunk = (rel >= 0) & (rel < C)
                safe = jnp.clip(rel, 0, C - 1)
                row = jnp.take_along_axis(
                    logits, safe[:, None, None], axis=1)[:, 0]   # (B, V)
                tok = jnp.where(in_chunk,
                                jnp.argmax(row, -1).astype(jnp.int32), tok)
            return tok, _merge_slots(cache, new_cache, admit)

        return jax.jit(fn)

    def _make_decode(self):
        model = self.model

        def fn(params, tok, cache, pos):
            logits, new_cache = model.decode_step(params, tok[:, None],
                                                  cache, pos)
            nxt = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)
            return nxt, new_cache

        return jax.jit(fn)

    # -- request flow ----------------------------------------------------

    def submit(self, req: Request) -> None:
        req.t_submit = self.clock()
        self.scheduler.submit(req)

    def _finish(self, bi: int, slot: int) -> None:
        bs = self.state[bi]
        req = self.scheduler.release(bi, slot)
        req.t_done = self.clock()
        self.results[req.rid] = ServeResult(
            rid=req.rid, tokens=list(bs.gen[slot]),
            prompt_len=req.prompt_len, bucket=bs.spec.name,
            t_submit=req.t_submit, t_admit=req.t_admit,
            t_first=req.t_first, t_done=req.t_done)
        bs.active[slot] = False
        bs.req[slot] = None
        bs.gen[slot] = []
        bs.pos[slot] = 0
        bs.last_tok[slot] = 0

    def _append_token(self, bi: int, slot: int, tok: int) -> None:
        bs = self.state[bi]
        req = bs.req[slot]
        bs.gen[slot].append(int(tok))
        bs.last_tok[slot] = tok
        done = len(bs.gen[slot]) >= req.max_new_tokens or \
            (req.eos_id >= 0 and int(tok) == req.eos_id)
        if done:
            self._finish(bi, slot)

    def step(self) -> None:
        """One engine tick: admit queued requests (per-bucket prefill),
        then one decode step for every bucket with active slots."""
        admissions = self.scheduler.admit()
        for bi, lst in admissions.items():
            bs = self.state[bi]
            P = self._prefill_width(bs.spec)
            toks = np.zeros((bs.spec.batch, P), np.int32)
            admit = np.zeros(bs.spec.batch, bool)
            last_idx = np.zeros(bs.spec.batch, np.int32)
            for slot, req in lst:
                plen = req.prompt_len
                toks[slot, :plen] = req.prompt
                admit[slot] = True
                last_idx[slot] = plen - 1
                bs.req[slot] = req
                bs.gen[slot] = []
            tok, bs.cache = self._prefill_fns[bi](
                self.params, jnp.asarray(toks), bs.cache,
                jnp.asarray(admit), jnp.asarray(last_idx))
            self.n_prefill_calls += 1
            tok = np.asarray(tok)
            now = self.clock()
            for slot, req in lst:
                req.t_admit = now
                req.t_first = now
                bs.active[slot] = True
                bs.pos[slot] = req.prompt_len
                self._append_token(bi, slot, tok[slot])

        for bi, bs in enumerate(self.state):
            if not bs.active.any():
                continue
            nxt, bs.cache = self._decode_fns[bi](
                self.params, jnp.asarray(bs.last_tok), bs.cache,
                jnp.asarray(bs.pos))
            self.n_decode_calls += 1
            nxt = np.asarray(nxt)
            for slot in np.flatnonzero(bs.active.copy()):
                bs.pos[slot] += 1
                self._append_token(bi, int(slot), nxt[slot])

    def run_until_drained(self, max_ticks: int = 1_000_000) -> None:
        for _ in range(max_ticks):
            if self.scheduler.idle:
                return
            self.step()
        raise RuntimeError(f"not drained after {max_ticks} ticks")

    # -- invariants ------------------------------------------------------

    def compile_counts(self) -> Dict[str, Dict[str, int]]:
        """Per-bucket compiled-program census — the zero-retrace
        acceptance property: steady state is exactly 1 prefill + 1
        decode executable per bucket."""
        return {b.name: {"prefill": self._prefill_fns[i]._cache_size(),
                         "decode": self._decode_fns[i]._cache_size()}
                for i, b in enumerate(self.scheduler.buckets)}


# -------------------------------------------------------- CNN scoring path


@dataclass
class ClassifyResult:
    rid: int
    label: int
    confidence: float
    bucket: str
    t_submit: float
    t_done: float

    @property
    def latency(self) -> float:
        return self.t_done - self.t_submit


class ImageClassifier:
    """Batched image-classification scoring for the paper's CNN
    clients: requests drain through per-batch-bucket compiled scoring
    programs (pad to the bucket, forward, argmax + softmax confidence).
    The same static-shape discipline: one program per batch bucket."""

    def __init__(self, model: Model, params,
                 batch_buckets: Sequence[int] = (1, 4, 8),
                 *, clock=time.perf_counter):
        if model.cfg.family != "cnn":
            raise ValueError(f"ImageClassifier needs a cnn family model, "
                             f"got '{model.cfg.family}'")
        self.model = model
        self.params = params
        self.buckets = tuple(sorted(set(int(b) for b in batch_buckets)))
        self.clock = clock
        self.results: Dict[int, ClassifyResult] = {}
        self._fns = {b: self._make_score(b) for b in self.buckets}

    def _make_score(self, batch: int):
        model = self.model

        def fn(params, images):
            logits, _ = model.forward(params, {"images": images})
            probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
            return (jnp.argmax(logits, -1).astype(jnp.int32),
                    jnp.max(probs, -1))

        return jax.jit(fn)

    def _pick_bucket(self, n: int) -> int:
        fits = [b for b in self.buckets if b <= n]
        return max(fits) if fits else self.buckets[0] if n else 0

    def classify(self, requests: Sequence[Request]) -> List[ClassifyResult]:
        """Drain a queue of image requests in bucket-sized groups
        (largest bucket that the remaining queue fills; the tail pads
        the smallest bucket)."""
        queue = list(requests)
        now = self.clock()
        for r in queue:
            r.t_submit = now
        out: List[ClassifyResult] = []
        i = 0
        while i < len(queue):
            remaining = len(queue) - i
            b = self._pick_bucket(remaining)
            if b == 0:
                break
            group = queue[i:i + min(b, remaining)]
            imgs = np.stack([r.image for r in group])
            if len(group) < b:                    # pad the tail group
                pad = np.zeros((b - len(group),) + imgs.shape[1:],
                               imgs.dtype)
                imgs = np.concatenate([imgs, pad])
            label, conf = self._fns[b](self.params, jnp.asarray(imgs))
            label, conf = np.asarray(label), np.asarray(conf)
            t_done = self.clock()
            for j, r in enumerate(group):
                r.t_done = t_done
                res = ClassifyResult(rid=r.rid, label=int(label[j]),
                                     confidence=float(conf[j]),
                                     bucket=f"b{b}", t_submit=r.t_submit,
                                     t_done=t_done)
                self.results[r.rid] = res
                out.append(res)
            i += len(group)
        return out

    def compile_counts(self) -> Dict[str, int]:
        return {f"b{b}": fn._cache_size() for b, fn in self._fns.items()}
