"""Train-to-serve bridge: load a fleet-driver checkpoint and serve it.

``repro.launch.fleet_driver --ckpt out/fleet`` exports the swarm's
final aggregated client-stacked params plus a manifest whose ``extra``
carries everything needed to rebuild the model *without the training
code path*: the full ``ModelConfig`` asdict, the client count and the
per-client sample weights. :func:`load_checkpoint` inverts that —
rebuild the config, ``build_model`` (a functools.cache hit for equal
frozen configs), restore against a ShapeDtypeStruct example tree (no
init compute), and reduce the client axis to the single served model.

Reduction policies (``client=``):

* ``"mean"``  — Eq. 2 with one global cluster (|D_h|-weighted mean over
  clients). After the driver's final in-checkpoint Eq. 2 every client
  already holds its cluster aggregate, so this is the cross-cluster
  global model.
* ``"client:i"`` — serve client ``i``'s (cluster's) model verbatim.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.checkpoint import restore_into
from repro.models import build_model
from repro.models.model import Model
from repro.serve.engine import ImageClassifier, ServeEngine, ServeResult
from repro.serve.scheduler import BucketSpec, Request, default_bucket_layout


def reduce_clients(sparams, weights, client: str = "mean"):
    """Collapse the leading client axis to one served parameter tree."""
    if client == "mean":
        w = jnp.asarray(weights, jnp.float32)
        w = w / jnp.maximum(w.sum(), 1e-9)

        def mean(x):
            wb = w.reshape((-1,) + (1,) * (x.ndim - 1))
            return (x.astype(jnp.float32) * wb).sum(0).astype(x.dtype)

        return jax.tree.map(mean, sparams)
    if client.startswith("client:"):
        i = int(client.split(":", 1)[1])
        return jax.tree.map(lambda x: x[i], sparams)
    raise ValueError(f"unknown reduction '{client}' "
                     "(want 'mean' or 'client:<i>')")


def load_checkpoint(path, *, client: str = "mean",
                    use_pallas: Optional[bool] = None
                    ) -> Tuple[Model, object]:
    """Restore a fleet checkpoint into ``(model, params)`` ready to
    serve. ``use_pallas`` overrides the trained config's kernel flag
    (serve on TPU what was swarm-trained with the jnp path, or vice
    versa — params are identical either way)."""
    path = Path(path)
    manifest = json.loads(path.with_suffix(".json").read_text())
    extra = manifest.get("extra", {})
    if "model_config" not in extra:
        raise ValueError(
            f"{path}: manifest has no 'model_config' — was this saved by "
            "fleet_driver --ckpt?")
    cfg = ModelConfig(**extra["model_config"])
    if use_pallas is not None and use_pallas != cfg.use_pallas:
        cfg = dataclasses.replace(cfg, use_pallas=use_pallas)
    model = build_model(cfg)
    n = int(extra.get("n_clients", 1))
    # example tree via eval_shape: restore_into only reads .shape/.dtype
    example = jax.eval_shape(
        lambda: jax.vmap(model.init)(
            jax.random.split(jax.random.PRNGKey(0), n)))
    sparams, _step = restore_into(example, path)
    weights = np.asarray(extra.get("client_weights", [1.0] * n), np.float32)
    return model, reduce_clients(sparams, weights, client)


# --------------------------------------------------------- one-call servers


def make_engine(model: Model, params, *, max_seq: int = 0,
                buckets: Optional[Sequence[BucketSpec]] = None,
                slots: int = 8, n_buckets: int = 2,
                prefill_chunk: int = 0) -> ServeEngine:
    """Build a :class:`ServeEngine` with either an explicit bucket
    layout or the default pow2 ladder up to ``max_seq``."""
    if buckets is None:
        if max_seq <= 0:
            raise ValueError("need max_seq (or explicit buckets)")
        buckets = default_bucket_layout(max_seq, slots=slots,
                                        n_buckets=n_buckets)
    return ServeEngine(model, params, buckets, prefill_chunk=prefill_chunk)


def generate(model: Model, params, prompts: Sequence[np.ndarray],
             max_new_tokens: int = 16, *, eos_id: int = -1,
             max_seq: int = 0, buckets=None, slots: int = 8,
             n_buckets: int = 2, prefill_chunk: int = 0,
             return_engine: bool = False) -> List[ServeResult]:
    """Batch-generate through the continuous-batching engine: submit
    every prompt, drain, return per-request :class:`ServeResult`\\ s in
    submission order. The one-call replacement for the old
    ``launch.serve`` per-token loop."""
    if max_seq <= 0 and buckets is None:
        max_seq = max(len(p) + max_new_tokens for p in prompts)
    eng = make_engine(model, params, max_seq=max_seq, buckets=buckets,
                      slots=slots, n_buckets=n_buckets,
                      prefill_chunk=prefill_chunk)
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=np.asarray(p, np.int32),
                           max_new_tokens=max_new_tokens, eos_id=eos_id))
    eng.run_until_drained()
    results = [eng.results[rid] for rid in range(len(prompts))]
    return (results, eng) if return_engine else results


def classify(model: Model, params, images: Sequence[np.ndarray],
             batch_buckets: Sequence[int] = (1, 4, 8)):
    """Batched image-classification scoring for the paper's CNN swarm
    models — the DR-grading serve path."""
    clf = ImageClassifier(model, params, batch_buckets)
    reqs = [Request(rid=i, image=np.asarray(im)) for i, im in enumerate(images)]
    return clf.classify(reqs)
