"""Request queue + slot scheduler for the continuous-batching engine.

The serving layout mirrors the training engine's static-shape
discipline (``run_grid`` / ``BucketedSwarmData``): the cache pool is a
fixed set of **size buckets**, each a ``BucketSpec(batch, seq)`` — a
block of ``batch`` cache slots whose sequence ceiling is ``seq``. A
request (arbitrary prompt length + generation budget) is routed to the
*smallest* bucket whose ceiling fits ``prompt_len + max_new_tokens``
and admitted when one of that bucket's slots is free; otherwise it
waits in the FIFO queue. Because every program the engine compiles is
keyed only on ``(batch, seq)``, steady-state serving runs with exactly
one prefill and one decode executable per bucket — zero per-request
retraces.

Admission is FIFO *per bucket*: a request that cannot be admitted does
not block requests bound for other buckets (no head-of-line blocking
across size classes), but never spills to a larger bucket — routing is
deterministic in the request alone.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

# ------------------------------------------------------------------ requests


@dataclass
class Request:
    """One generation (or classification) request.

    LM requests carry ``prompt`` (1-D int32 tokens) and
    ``max_new_tokens``; CNN scoring requests carry ``image`` instead
    (see ``repro.serve.engine.ImageClassifier``). Timestamps are
    stamped by the engine: ``t_submit`` at queue entry, ``t_admit``
    when a slot is taken, ``t_first`` at the first generated token
    (prefill exit), ``t_done`` at completion.
    """
    rid: int
    prompt: Optional[np.ndarray] = None
    max_new_tokens: int = 0
    image: Optional[np.ndarray] = None
    eos_id: int = -1                     # -1: generate exactly max_new
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0

    @property
    def prompt_len(self) -> int:
        return 0 if self.prompt is None else int(len(self.prompt))

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.max_new_tokens


# ------------------------------------------------------------------- buckets


@dataclass(frozen=True)
class BucketSpec:
    """One cache-slot block: ``batch`` slots of sequence ceiling
    ``seq``. ``prompt_ceiling`` bounds admissible prompt lengths (it
    equals ``seq`` except for ring-buffer caches, where the prefill
    window is the ring length)."""
    batch: int
    seq: int
    prompt_ceiling: int = 0

    def __post_init__(self):
        if self.batch < 1 or self.seq < 1:
            raise ValueError(f"bad bucket {self.batch}x{self.seq}")
        if self.prompt_ceiling <= 0:
            object.__setattr__(self, "prompt_ceiling", self.seq)

    @property
    def name(self) -> str:
        return f"b{self.batch}xs{self.seq}"


def default_bucket_layout(max_seq: int, *, slots: int = 8,
                          n_buckets: int = 2) -> Tuple[BucketSpec, ...]:
    """A pow2 ladder of sequence ceilings ending at ``max_seq`` with
    the slot budget split evenly — the serving analogue of
    ``repro.data.dr.bucket_clients``'s pow2 strategy."""
    if max_seq < 2 ** (n_buckets - 1):
        raise ValueError(f"max_seq={max_seq} too small for {n_buckets} buckets")
    seqs = [max(1, max_seq // 2 ** (n_buckets - 1 - i))
            for i in range(n_buckets)]
    per = max(1, slots // n_buckets)
    return tuple(BucketSpec(batch=per, seq=s) for s in seqs)


# ----------------------------------------------------------------- scheduler


class SlotScheduler:
    """FIFO queue + per-bucket free-slot admission."""

    def __init__(self, buckets):
        self.buckets: Tuple[BucketSpec, ...] = tuple(buckets)
        if not self.buckets:
            raise ValueError("need at least one bucket")
        self.queue: deque = deque()
        self.free: List[List[int]] = [list(range(b.batch))
                                      for b in self.buckets]
        self.running: Dict[Tuple[int, int], Request] = {}
        self.n_submitted = 0
        self.n_done = 0

    # -- routing --------------------------------------------------------

    def bucket_for(self, req: Request) -> Optional[int]:
        """Smallest-ceiling bucket that fits the request, or None."""
        best, best_seq = None, None
        for i, b in enumerate(self.buckets):
            if req.total_len <= b.seq and req.prompt_len <= b.prompt_ceiling:
                if best_seq is None or (b.seq, b.batch) < best_seq:
                    best, best_seq = i, (b.seq, b.batch)
        return best

    # -- queue ----------------------------------------------------------

    def submit(self, req: Request) -> int:
        bi = self.bucket_for(req)
        if bi is None:
            raise ValueError(
                f"request {req.rid} (prompt {req.prompt_len} + "
                f"{req.max_new_tokens} new) fits no bucket "
                f"{[b.name for b in self.buckets]}")
        self.queue.append(req)
        self.n_submitted += 1
        return bi

    def admit(self) -> Dict[int, List[Tuple[int, Request]]]:
        """Move queued requests into free slots. Returns
        ``{bucket_idx: [(slot, request), ...]}`` for this round's
        admissions; requests whose bucket is full keep their queue
        order."""
        admitted: Dict[int, List[Tuple[int, Request]]] = {}
        waiting: deque = deque()
        while self.queue:
            req = self.queue.popleft()
            bi = self.bucket_for(req)
            if self.free[bi]:
                slot = self.free[bi].pop(0)
                self.running[(bi, slot)] = req
                admitted.setdefault(bi, []).append((slot, req))
            else:
                waiting.append(req)
        self.queue = waiting
        return admitted

    def release(self, bucket_idx: int, slot: int) -> Request:
        req = self.running.pop((bucket_idx, slot))
        self.free[bucket_idx].append(slot)
        self.free[bucket_idx].sort()
        self.n_done += 1
        return req

    # -- introspection ---------------------------------------------------

    @property
    def idle(self) -> bool:
        return not self.queue and not self.running

    def occupancy(self) -> Dict[str, float]:
        """Fraction of each bucket's slots currently running."""
        return {b.name: 1.0 - len(self.free[i]) / b.batch
                for i, b in enumerate(self.buckets)}
