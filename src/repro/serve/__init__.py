"""Serving subsystem: continuous-batching inference for swarm-trained
models, ``flash_decode`` on the hot path (use_pallas), with the same
static-shape/one-program-per-bucket discipline as the training engine.
"""
from repro.serve.api import (classify, generate, load_checkpoint,  # noqa: F401
                             make_engine, reduce_clients)
from repro.serve.engine import (ClassifyResult, ImageClassifier,  # noqa: F401
                                ServeEngine, ServeResult)
from repro.serve.scheduler import (BucketSpec, Request,  # noqa: F401
                                   SlotScheduler, default_bucket_layout)
