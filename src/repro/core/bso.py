"""Brain Storm Aggregation (paper §III.C).

Host-side coordinator logic — deliberately lightweight, mirroring the
paper's server whose *only* job is assigning neighbours:

  1. **Select cluster center** — the best validation score in each
     cluster.
  2. **Brain storm** — per cluster draw r1~U[0,1]; if r1 > p1 replace
     the center with a random member. Then per cluster draw r2; if
     r2 > p2 swap this cluster's center with another cluster's center
     (the swapped clients trade cluster membership for this round's
     aggregation — the "exchange individuals between clusters" move
     that fights non-IID local optima).
  3. **Parameter aggregation** — Eq. 2: sample-count-weighted FedAvg
     within each (post-swap) cluster; the jit-able segment-sum version
     lives in :mod:`repro.core.aggregation`.

With the paper's p1=0.9 / p2=0.8 and r > p triggering, disruption rates
are 10% / 20% per cluster per round.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List

import numpy as np


@dataclass
class BSAPlan:
    """The coordinator's per-round output."""
    assignments: np.ndarray            # (N,) effective cluster of each client
    centers: np.ndarray                # (K,) client index of each cluster center
    events: List[str] = field(default_factory=list)


def brain_storm(rng: np.random.Generator, assignments: np.ndarray,
                val_scores: np.ndarray, k: int, p1: float, p2: float) -> BSAPlan:
    """Pure host-side BSA planning. ``assignments`` come from k-means on
    the distribution summaries; ``val_scores`` are the clients' local
    validation accuracies (shared within the cluster, paper step 1)."""
    assignments = np.asarray(assignments).copy()
    val_scores = np.asarray(val_scores)
    N = assignments.shape[0]
    events: List[str] = []

    # 1. centers = best validation score per cluster
    centers = np.full((k,), -1, dtype=np.int64)
    for c in range(k):
        members = np.where(assignments == c)[0]
        if len(members) == 0:
            continue
        centers[c] = members[np.argmax(val_scores[members])]

    # 2a. random center replacement (r1 > p1)
    for c in range(k):
        members = np.where(assignments == c)[0]
        if len(members) == 0:
            continue
        r1 = rng.uniform()
        if r1 > p1:
            new_center = int(rng.choice(members))
            if new_center != centers[c]:
                events.append(f"replace: cluster {c} center "
                              f"{centers[c]} -> {new_center} (r1={r1:.3f})")
            centers[c] = new_center

    # 2b. cross-cluster center swap (r2 > p2)
    occupied = [c for c in range(k) if centers[c] >= 0]
    for c in occupied:
        r2 = rng.uniform()
        if r2 > p2 and len(occupied) > 1:
            other = int(rng.choice([o for o in occupied if o != c]))
            ci, oi = centers[c], centers[other]
            centers[c], centers[other] = oi, ci
            # the swapped clients also trade aggregation membership
            assignments[ci], assignments[oi] = assignments[oi], assignments[ci]
            events.append(f"swap: centers of clusters {c} and {other} "
                          f"(clients {ci} <-> {oi}, r2={r2:.3f})")

    return BSAPlan(assignments=assignments, centers=centers, events=events)
