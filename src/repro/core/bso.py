"""Brain Storm Aggregation (paper §III.C).

The coordinator's per-round decision, mirroring the paper's server
whose *only* job is assigning neighbours:

  1. **Select cluster center** — the best validation score in each
     cluster.
  2. **Brain storm** — per cluster draw r1~U[0,1]; if r1 > p1 replace
     the center with a random member. Then per cluster draw r2; if
     r2 > p2 swap this cluster's center with another cluster's center
     (the swapped clients trade cluster membership for this round's
     aggregation — the "exchange individuals between clusters" move
     that fights non-IID local optima).
  3. **Parameter aggregation** — Eq. 2: sample-count-weighted FedAvg
     within each (post-swap) cluster; the jit-able segment-sum version
     lives in :mod:`repro.core.aggregation`.

Two implementations of the same decision procedure:

* :func:`brain_storm_jax` — the engine path (`repro.core.engine`):
  fixed-shape, `jax.random`-key-driven, fully traceable, so the whole
  BSO round (local steps + coordinator + Eq. 2) fuses into ONE jit'd
  device program and scans over rounds. Centers come from a masked
  per-cluster argmax, random members from a masked Gumbel-argmax, and
  the sequential cross-cluster swaps unroll over the static ``k``.
* :func:`brain_storm` — the original host-side numpy version, kept as
  the parity oracle (the two consume different RNG streams, so parity
  is statistical: same event *rates*, same structural invariants).

With the paper's p1=0.9 / p2=0.8 and r > p triggering, disruption rates
are 10% / 20% per cluster per round.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class BSAPlan:
    """The coordinator's per-round output."""
    assignments: np.ndarray            # (N,) effective cluster of each client
    centers: np.ndarray                # (K,) client index of each cluster center
    events: List[str] = field(default_factory=list)


def brain_storm(rng: np.random.Generator, assignments: np.ndarray,
                val_scores: np.ndarray, k: int, p1: float, p2: float) -> BSAPlan:
    """Pure host-side BSA planning. ``assignments`` come from k-means on
    the distribution summaries; ``val_scores`` are the clients' local
    validation accuracies (shared within the cluster, paper step 1)."""
    assignments = np.asarray(assignments).copy()
    val_scores = np.asarray(val_scores)
    events: List[str] = []

    # 1. centers = best validation score per cluster
    centers = np.full((k,), -1, dtype=np.int64)
    for c in range(k):
        members = np.where(assignments == c)[0]
        if len(members) == 0:
            continue
        centers[c] = members[np.argmax(val_scores[members])]

    # 2a. random center replacement (r1 > p1)
    for c in range(k):
        members = np.where(assignments == c)[0]
        if len(members) == 0:
            continue
        r1 = rng.uniform()
        if r1 > p1:
            new_center = int(rng.choice(members))
            if new_center != centers[c]:
                events.append(f"replace: cluster {c} center "
                              f"{centers[c]} -> {new_center} (r1={r1:.3f})")
            centers[c] = new_center

    # 2b. cross-cluster center swap (r2 > p2)
    occupied = [c for c in range(k) if centers[c] >= 0]
    for c in occupied:
        r2 = rng.uniform()
        if r2 > p2 and len(occupied) > 1:
            other = int(rng.choice([o for o in occupied if o != c]))
            ci, oi = centers[c], centers[other]
            centers[c], centers[other] = oi, ci
            # the swapped clients also trade aggregation membership
            assignments[ci], assignments[oi] = assignments[oi], assignments[ci]
            events.append(f"swap: centers of clusters {c} and {other} "
                          f"(clients {ci} <-> {oi}, r2={r2:.3f})")

    return BSAPlan(assignments=assignments, centers=centers, events=events)


def brain_storm_jax(key, assignments, val_scores, k: int, p1, p2):
    """Traceable BSA planning — the same decision procedure as
    :func:`brain_storm`, expressed in fixed shapes over a static ``k``.

    assignments: (N,) int cluster ids from k-means.
    val_scores:  (N,) float local validation accuracies.
    p1, p2:      python floats *or* traced scalars — they only enter
                 ``r > p`` comparisons, so the grid engine threads them
                 as per-row data through one compiled program.

    ``k`` is the static *pad*: per-cluster randomness derives from
    ``fold_in(key, c)`` (not a shape-``(k,)`` draw), so cluster c's
    draws are identical under any static ``k > c``. Clusters that are
    empty — including masked-off pad slots when k-means ran with
    ``k_active < k`` — are unoccupied and never replace, swap, or count,
    which makes a padded run bitwise-equal to a natively smaller-k run.

    Returns ``(assignments, centers, n_replaced, n_swapped)``:
    post-swap (N,) assignments, (k,) center client indices (-1 for an
    empty cluster), and the round's event counts (replacing the numpy
    version's event strings — the only host-facing residue).
    """
    a = jnp.asarray(assignments, jnp.int32)
    val = jnp.asarray(val_scores, jnp.float32)
    member = a[None, :] == jnp.arange(k, dtype=jnp.int32)[:, None]   # (k, N)
    occupied = jnp.any(member, axis=1)                               # (k,)
    n_occ = jnp.sum(occupied.astype(jnp.int32))

    # 1. centers = best validation score per cluster (masked argmax)
    centers = jnp.argmax(jnp.where(member, val[None, :], -jnp.inf),
                         axis=1).astype(jnp.int32)
    centers = jnp.where(occupied, centers, -1)

    k_rep, k_member, k_swap, k_other = jax.random.split(key, 4)
    cluster_ids = jnp.arange(k, dtype=jnp.uint32)

    # 2a. random center replacement (r1 > p1): a uniformly random member
    # per cluster via masked Gumbel-argmax (one draw per (cluster,
    # client), no data-dependent shapes)
    r1 = jax.vmap(lambda c: jax.random.uniform(
        jax.random.fold_in(k_rep, c)))(cluster_ids)
    g = jax.vmap(lambda c: jax.random.gumbel(
        jax.random.fold_in(k_member, c), (a.shape[0],)))(cluster_ids)
    rand_member = jnp.argmax(jnp.where(member, g, -jnp.inf),
                             axis=1).astype(jnp.int32)
    do_rep = (r1 > p1) & occupied
    n_replaced = jnp.sum((do_rep & (rand_member != centers)).astype(jnp.int32))
    centers = jnp.where(do_rep, rand_member, centers)

    # 2b. sequential cross-cluster center swaps (r2 > p2). Later swaps
    # must see earlier ones (same as the host loop), so unroll over the
    # static k; the swap partner is a uniformly random *other* occupied
    # cluster via masked Gumbel-argmax. The partner gumbels are drawn
    # per (c, other) pair so pad slots never perturb the real pairs.
    r2 = jax.vmap(lambda c: jax.random.uniform(
        jax.random.fold_in(k_swap, c)))(cluster_ids)
    g2 = jax.vmap(lambda c: jax.vmap(lambda o: jax.random.gumbel(
        jax.random.fold_in(jax.random.fold_in(k_other, c), o)))(
            cluster_ids))(cluster_ids)
    n_swapped = jnp.zeros((), jnp.int32)
    for c in range(k):
        valid_other = occupied & (jnp.arange(k) != c)
        other = jnp.argmax(jnp.where(valid_other, g2[c], -jnp.inf)
                           ).astype(jnp.int32)
        do_swap = (r2[c] > p2) & occupied[c] & (n_occ > 1)
        ci, oi = centers[c], centers[other]
        swapped_centers = centers.at[c].set(oi).at[other].set(ci)
        swapped_a = a.at[ci].set(a[oi]).at[oi].set(a[ci])
        centers = jnp.where(do_swap, swapped_centers, centers)
        a = jnp.where(do_swap, swapped_a, a)
        n_swapped = n_swapped + do_swap.astype(jnp.int32)

    return a, centers, n_replaced, n_swapped
