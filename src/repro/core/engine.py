"""Functional BSO-SL round engine: ONE jit'd program per round.

The paper's round (§III) — local SGD → distribution upload → k-means →
brain-storm aggregation — is expressed here as a pure function over an
explicit :class:`SwarmState` pytree::

    state, metrics = swarm_round(state, data, cfg)

Everything inside is traceable: local-training batches are sampled
on-device (`jax.random` gather over the device-resident stacked
dataset in :class:`SwarmData`), the coordinator runs the jax
``brain_storm_jax`` port, and Eq. 2 aggregation is the segment-sum
``cluster_fedavg``. A whole sim-regime round is therefore a single
device program, and :func:`run_rounds` scans it over rounds so a full
``fit`` is ONE program too.

Both regimes share this body:

* **sim** — :func:`swarm_round`; the stateful
  :class:`repro.core.swarm.SwarmTrainer` is a thin host wrapper.
* **fleet** — :func:`make_fleet_round` composes the same
  :func:`local_phase` + in-program distribution-stat upload
  (``param_stats_batched`` under ``use_pallas``) + ``cluster_fedavg``;
  only the O(clients) coordinator decision (k-means + brain storm)
  arrives from the host, matching the paper's neighbour-assignment
  server (see ``repro/launch/swarm_fleet.py``).

The round also carries a **method axis** (paper Table II): the four
comparison methods are parameterisations of this one body, realised as
the traced :class:`MethodParams` masks —

* ``centralized``  — every client samples the pooled global dataset
  (the "1 merged client" upper bound, batched over N replicas) and
  aggregates into one global model each round,
* ``local``        — singleton clusters: Eq. 2 is the bitwise identity,
* ``fedavg``       — one global cluster, no coordinator decision,
* ``bso-sl``       — the full k-means + brain-storm path.

Because the differences are traced data (a pooling flag and a fallback
assignment vector), ONE compiled program serves the whole axis:
:func:`run_sweep` vmaps :func:`run_rounds` over stacked
:class:`MethodParams` + per-method :class:`SwarmState`, sharing a
single device-resident :class:`SwarmData` — the paper's Table II grid
(4 methods x rounds programs) collapses to one executable.

The same move generalises to **hyper-parameter grids** (the knobs the
paper fixes without ablation — k=3, p1=0.9, p2=0.8): a
:class:`GridPoint` carries the BSO knobs (cluster count, p1, p2,
local-step and lr overrides) as traced per-row data on top of the
:class:`MethodParams` masks. The cluster count rides a masked
static-max path — ``cfg.n_clusters`` is the pad ``k_max``, k-means and
the brain storm mask clusters ``>= point.n_clusters`` — and the local
phase applies only the first ``point.local_steps`` updates. So
:func:`run_grid` vmaps :func:`run_rounds` over stacked
:class:`GridPoint` rows and a whole (k x p1 x p2) ablation lowers to
ONE executable too, again sharing one device-resident
:class:`SwarmData`. Each grid row is bitwise-equal to the serial
single-point program, and a padded-k row is bitwise-equal to a native
smaller-k run (``tests/test_grid.py``).

And to **scenarios**: real fleets churn — clients drop, lag, and
rejoin. :class:`ChurnParams` makes that a traced axis on the same one
program: a per-round participation mask (seeded Bernoulli dropout or an
explicit schedule) under which absent clients run masked no-op local
steps, keep their stale params through Eq. 2 (the masked
``cluster_fedavg_masked`` with an all-absent-cluster fallback), and
drop out of the k-means stats matrix (masked points ride the existing
empty-cluster reseed); a ``stale_decay`` knob turns hard masking into
staleness-weighted aggregation (weight ``|D_h| * decay^staleness``,
counters carried in :attr:`SwarmState.staleness`). ``dropout`` /
``stale_decay`` / ``churn_mask`` are :class:`GridPoint` axes, so a
dropout-robustness sweep is ONE executable; an all-ones mask is bitwise
the churn-free engine (``tests/test_churn.py``).

Contract summary (the stable public surface):

* :class:`SwarmState` — the complete mutable swarm (params, opt state,
  PRNG key, round counter, Eq. 2 sample weights), one pytree.
* :class:`SwarmData` — the device-resident fixed-shape dataset
  (padded train stack + sampling bounds + masked eval stacks).
* :class:`EngineConfig` — the static (hashable) round configuration;
  equal configs share one compiled program.
* :class:`MethodParams` / :class:`GridPoint` — traced per-row axes:
  what the paper varies, expressed as data instead of control flow.
* :func:`swarm_round` / :func:`run_rounds` / :func:`run_sweep` /
  :func:`run_grid` — one round / one fit / the Table-II axis / a
  hyper-parameter grid, each as ONE device program.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SwarmConfig
from repro.core.aggregation import (cluster_fedavg, cluster_fedavg_masked,
                                    cluster_fedavg_psum,
                                    cluster_fedavg_psum_masked,
                                    singleton_assignments)
from repro.core.bso import brain_storm_jax
from repro.core.diststats import swarm_distribution_matrix
from repro.core.kmeans import kmeans
from repro.models.model import Model
from repro.optim.optimizers import Optimizer
from repro.train.steps import make_eval_step, make_train_step

# --------------------------------------------------------------------- state


class SwarmState(NamedTuple):
    """The complete mutable state of a swarm, as one pytree.

    Every field has a leading client axis N where applicable, so the
    state threads through jit/scan/donation without host round-trips.
    """
    params: Any                      # client-stacked model pytree (N, ...)
    opt_state: Any                   # client-stacked optimizer pytree
    key: Any                         # PRNG key driving sampling + BSA
    round: Any                       # () int32 round counter
    n_samples: Any                   # (N,) float32 |D_h| (Eq. 2 weights)
    staleness: Any = None            # (N,) int32 rounds since last
    #                                  participation (0 = participated
    #                                  this round) — the churn axis's
    #                                  carried counter; None on states
    #                                  predating the churn engine


class SwarmData(NamedTuple):
    """Device-resident, fixed-shape swarm dataset.

    train:   batch pytree with shape (N, n_max, ...); clients shorter
             than n_max are padded (pad rows are never sampled).
    train_n: (N,) int32 true train-set sizes — the sampling bound.
    val:     client-stacked eval batches (N, n_batches, batch, ...)
             with label=-1 masking (see :func:`stack_eval_split`).
    """
    train: Any
    train_n: Any
    val: Any


@jax.tree_util.register_pytree_node_class
class BucketedSwarmData:
    """Size-bucketed, ragged-aware sibling of :class:`SwarmData`.

    A skewed swarm (paper Table I: clinic sizes 14..974) pays for the
    rectangular layout twice — every client's train stack and eval
    stack are padded to the *global* maximum. This layout groups
    clients into a few size buckets (:func:`repro.data.dr.
    bucket_clients`) and pads each bucket only to its own ceiling:

    train:      tuple of per-bucket batch pytrees, bucket b shaped
                (N_b, n_max_b, ...) — pad rows never sampled.
    val:        tuple of per-bucket stacked eval splits, bucket b
                shaped (N_b, n_batches_b, batch, ...) with label=-1
                masking (:func:`stack_eval_split` layout per bucket).
    train_n:    (N,) int32 true train sizes in ORIGINAL client order —
                the same global sampling bound as :class:`SwarmData`,
                so index draws are bitwise layout-independent.
    client_ids: static tuple of per-bucket client-id tuples (ascending
                within a bucket; a partition of range(N)). Static
                (pytree aux data), so per-bucket gathers/scatters trace
                to fixed-shape ops and equal layouts share one compiled
                program — the same static-shape discipline as
                :func:`run_grid`.

    The engine dispatches on the layout (:func:`sample_round_batch`,
    :func:`eval_swarm`): every :func:`swarm_round` / :func:`run_rounds`
    / :func:`run_sweep` / :func:`run_grid` entry point accepts either,
    and the bucketed results are BITWISE the rectangular ones (pinned
    in ``tests/test_bucket.py``) — sampling draws the identical global
    index tensor and eval drops only all-pad microbatches whose
    contribution is exactly +0.0.
    """

    def __init__(self, train, val, train_n, client_ids):
        self.train = tuple(train)
        self.val = tuple(val)
        self.train_n = train_n
        self.client_ids = tuple(tuple(int(i) for i in ids)
                                for ids in client_ids)

    @property
    def n_buckets(self) -> int:
        return len(self.client_ids)

    def tree_flatten(self):
        return (self.train, self.val, self.train_n), self.client_ids

    @classmethod
    def tree_unflatten(cls, aux, children):
        train, val, train_n = children
        return cls(train, val, train_n, aux)


class RoundMetrics(NamedTuple):
    """Per-round outputs (all device scalars/arrays, scan-stackable)."""
    mean_val_acc: Any                # () — paper Eq. 3 on the val split
    val_acc: Any                     # (N,) per-client val accuracy
    train_loss: Any                  # () mean loss of the last local step
    assignments: Any                 # (N,) int32 post-BSA clusters
    centers: Any                     # (k,) int32 center client ids
    n_replaced: Any                  # () int32 BSA replacement events
    n_swapped: Any                   # () int32 BSA swap events
    present: Any = None              # (N,) bool participation mask of
    #                                  this round (all-ones when no
    #                                  churn axis is threaded)


class MethodParams(NamedTuple):
    """Traced per-method knobs — the Table-II method axis as data.

    Every field is a jax array (no python branches), so the four paper
    methods trace to the SAME program and :func:`run_sweep` can vmap
    over a stacked instance. ``base_assign`` is the aggregation plan
    used when the coordinator is masked off; the segment count is
    always N (see :func:`~repro.core.aggregation.cluster_fedavg`).
    """
    pool_data: Any        # () bool — sample minibatches from the pooled
                          #           global dataset (centralized)
    use_coord: Any        # () bool — take the k-means + brain-storm
                          #           assignments (bso-sl)
    base_assign: Any      # (N,) int32 — assignments when not use_coord:
                          #           arange(N) local, zeros fedavg/centr.


#: Paper Table II method axis, in table order.
SWEEP_METHODS = ("centralized", "local", "fedavg", "bso-sl")


def method_params(method: str, n_clients: int) -> MethodParams:
    """The :class:`MethodParams` row realising one paper method.

    The axis is a *controlled same-budget* comparison: every method —
    centralized included — runs the same (rounds x local_steps x
    batch) grid. The paper's centralized number relied on a step count
    scaled by the clinic count; ``baselines.train_centralized`` keeps
    that paper-budget oracle for reference (table2 reports both).
    """
    if method not in SWEEP_METHODS:
        raise ValueError(f"unknown method {method!r}; one of {SWEEP_METHODS}")
    zeros = jnp.zeros((n_clients,), jnp.int32)
    return MethodParams(
        pool_data=jnp.asarray(method == "centralized"),
        use_coord=jnp.asarray(method == "bso-sl"),
        base_assign=singleton_assignments(n_clients) if method == "local"
        else zeros)


def make_sweep_config(n_clients: int,
                      methods=SWEEP_METHODS) -> MethodParams:
    """Stacked :class:`MethodParams` with a leading (M,) method axis —
    the ``SweepConfig`` that :func:`run_sweep` vmaps over."""
    rows = [method_params(m, n_clients) for m in methods]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *rows)


class ChurnParams(NamedTuple):
    """Traced per-round churn knobs — the scenario axis as engine data.

    Real fleets have clients that drop, lag and rejoin; this axis makes
    "how robust is BSO-SL at 30% dropout?" traced data on the same one
    compiled program, exactly the :class:`MethodParams` move:

    * an ABSENT client skips the local phase (masked no-op — keys are
      consumed unconditionally so every churn row shares one program),
      keeps its stale params (it never receives the round's Eq. 2
      aggregate), contributes zero — or a staleness-decayed echo — of
      weight to its cluster's Eq. 2 sum, and is excluded from the
      k-means stats matrix (see :mod:`repro.core.kmeans` masks; an
      all-absent cluster rides the existing empty-cluster reseed).
    * ``stale_decay`` = λ selects the aggregation semantics: the
      effective Eq. 2 weight of client h is ``|D_h| * λ^staleness``
      where ``staleness`` counts rounds since last participation
      (carried in :attr:`SwarmState.staleness`, reset to 0 on
      participation). λ=0 is the plain hard mask (``0^0 = 1`` keeps
      every present client at full weight), λ→1 lets stale params
      linger in the aggregate at decaying weight.

    ``dropout = 0.0`` (with no explicit mask) draws an all-ones mask,
    which is BITWISE the no-churn engine path — the parity anchor
    ``tests/test_churn.py`` pins.
    """
    dropout: Any          # () float32 — per-round P(client absent);
                          #   the Bernoulli draw rides a fold_in of the
                          #   round's sampling key (stream-disjoint)
    stale_decay: Any      # () float32 λ — Eq. 2 staleness weight decay
                          #   (0 = hard mask, see above)
    mask: Any = None      # optional explicit participation mask
                          #   overriding the Bernoulli draw: (N,) for
                          #   every round, or a (rounds, N) schedule
                          #   (run_rounds scans one row per round)


def churn_params(dropout: float = 0.0, stale_decay: float = 0.0,
                 mask=None) -> ChurnParams:
    """One :class:`ChurnParams` row. ``mask`` (optional) pins the
    participation pattern explicitly — (N,) for a fixed mask, or a
    (rounds, N) schedule consumed row-per-round by :func:`run_rounds`;
    without it each round Bernoulli-drops clients at ``dropout``."""
    d = float(dropout)
    if not 0.0 <= d <= 1.0:
        raise ValueError(f"dropout={d} outside [0, 1]")
    g = float(stale_decay)
    if not 0.0 <= g <= 1.0:
        raise ValueError(f"stale_decay={g} outside [0, 1]")
    if mask is not None:
        mask = jnp.asarray(mask, bool)
        if mask.ndim not in (1, 2):
            raise ValueError("churn mask must be (N,) or (rounds, N), "
                             f"got shape {mask.shape}")
    return ChurnParams(dropout=jnp.asarray(d, jnp.float32),
                       stale_decay=jnp.asarray(g, jnp.float32),
                       mask=mask)


class GridPoint(NamedTuple):
    """Traced per-row hyper-parameters — grid axes as engine data.

    A strict superset of the method axis: ``method`` is the Table-II
    mask row (grid rows default to the full bso-sl path) and the knobs
    override the corresponding :class:`EngineConfig` statics, which act
    as the row's *pads/maxima*:

    * ``n_clusters`` ``<= cfg.n_clusters`` (the static ``k_max``) —
      k-means + brain storm run masked to the first ``n_clusters``
      slots (see :mod:`repro.core.kmeans`),
    * ``local_steps`` ``<= cfg.local_steps`` — the local phase computes
      every static step but applies only the first ``local_steps``
      (the key stream is consumed unconditionally so all rows share
      one program),
    * ``p1`` / ``p2`` / ``lr`` — pure value overrides.

    Build rows with :func:`grid_point`, stack them with
    :func:`make_grid_config`, and :func:`run_grid` vmaps the fit over
    the stack.
    """
    method: MethodParams  # Table-II masks (pool_data/use_coord/base_assign)
    n_clusters: Any       # () int32 active cluster count, 1..cfg.n_clusters
    p1: Any               # () float32 center-replacement threshold
    p2: Any               # () float32 center-swap threshold
    local_steps: Any      # () int32 applied local steps, 1..cfg.local_steps
    lr: Any               # () float32 local-phase learning rate
    churn: Any = None     # ChurnParams scenario row, or None (no churn)


def grid_point(cfg: "EngineConfig", n_clients: int, *, method: str = "bso-sl",
               k=None, p1=None, p2=None, local_steps=None, lr=None,
               dropout=None, stale_decay=None, churn_mask=None) -> GridPoint:
    """One :class:`GridPoint` from a spec; ``None`` knobs inherit the
    engine-config value (so the empty spec is exactly the paper point).
    ``k``/``local_steps`` are validated against the static maxima at
    build time — the traced program only sees in-range values.

    ``dropout`` / ``stale_decay`` / ``churn_mask`` build a
    :class:`ChurnParams` scenario row (any of them given opts the row
    in; ``dropout=0.0`` is the bitwise no-churn anchor). Grid rows must
    be uniformly churn or churn-free — :func:`make_grid_config` checks.
    """
    k = cfg.n_clusters if k is None else int(k)
    if not 1 <= k <= cfg.n_clusters:
        raise ValueError(f"grid k={k} outside [1, {cfg.n_clusters}] — "
                         f"cfg.n_clusters is the static pad k_max")
    steps = cfg.local_steps if local_steps is None else int(local_steps)
    if not 1 <= steps <= cfg.local_steps:
        raise ValueError(f"grid local_steps={steps} outside "
                         f"[1, {cfg.local_steps}] — cfg.local_steps is "
                         f"the static step budget")
    churn = None
    if dropout is not None or stale_decay is not None \
            or churn_mask is not None:
        churn = churn_params(0.0 if dropout is None else dropout,
                             0.0 if stale_decay is None else stale_decay,
                             churn_mask)
    return GridPoint(
        method=method_params(method, n_clients),
        n_clusters=jnp.asarray(k, jnp.int32),
        p1=jnp.asarray(cfg.p1 if p1 is None else p1, jnp.float32),
        p2=jnp.asarray(cfg.p2 if p2 is None else p2, jnp.float32),
        local_steps=jnp.asarray(steps, jnp.int32),
        lr=jnp.asarray(cfg.lr if lr is None else lr, jnp.float32),
        churn=churn)


def grid_axes(**axes) -> list:
    """Cartesian product of named axes into grid-point specs::

        grid_axes(k=(1, 2, 3), p1=(0.9, 1.0))
        # -> [{'k': 1, 'p1': 0.9}, {'k': 1, 'p1': 1.0}, ...]

    Axis names are :func:`grid_point` keywords (``k``, ``p1``, ``p2``,
    ``local_steps``, ``lr``, ``method``, and the churn axes
    ``dropout`` / ``stale_decay`` / ``churn_mask``). Point order is
    row-major in the given axis order — the row order of
    :func:`make_grid_config`.
    """
    names = list(axes)
    return [dict(zip(names, combo))
            for combo in itertools.product(*(axes[n] for n in names))]


def make_grid_config(cfg: "EngineConfig", n_clients: int,
                     specs: Sequence[dict]) -> GridPoint:
    """Stacked :class:`GridPoint` with a leading (G,) grid axis — the
    grid that :func:`run_grid` vmaps over. ``specs`` is a list of
    :func:`grid_point` keyword dicts (see :func:`grid_axes`)."""
    rows = [grid_point(cfg, n_clients, **s) for s in specs]
    has_churn = [r.churn is not None for r in rows]
    if any(has_churn) and not all(has_churn):
        raise ValueError(
            "grid rows must be uniformly churn or churn-free (stacking "
            "mixes pytree structures); give the always-on rows "
            "dropout=0.0 — it is the bitwise no-churn anchor")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *rows)


@dataclass(frozen=True)
class HierParams:
    """Static two-tier coordination topology — the million-client axis.

    The flat coordinator is O(N) in clients: every round it clusters
    the full (N, 2*#tensors) stats matrix and brain-storms over N
    assignments. ``HierParams`` shards the swarm into *pods* that each
    run a local k-means over their own members' stats, and the global
    tier (k-means + brain storm) runs over the ``n_pods * k_local``
    pod-cluster summaries instead — centroids weighted by member
    counts (the :func:`repro.core.kmeans.kmeans` ``weights`` axis), a
    pod-cluster's val score the mean of its members'. A client's
    global cluster is the composition ``g[pod * k_local + a_local]``;
    Eq. 2 aggregation is unchanged (N-segment ``cluster_fedavg``), so
    only the *coordinator* shrinks from O(clients) to O(pods).

    Pod membership is STATIC (tuples — this dataclass is a jit static
    argument like :class:`EngineConfig`): the topology shapes the
    program, exactly as bucket membership does in
    :class:`BucketedSwarmData`. Unequal pods are fine in the sim
    engine; the fleet surface wants equal contiguous pods (one per
    mesh shard — see :func:`make_fleet_round`).

    ``hier=None`` everywhere is the flat path untouched; a single-pod
    ``HierParams`` routes to the flat coordinator *verbatim* (one pod
    means the pod-local clustering IS the global clustering, so the
    two-tier math degenerates — the engine short-circuits statically
    and ``tests/test_hier.py`` pins bitwise equality).
    """
    pods: tuple          # tuple[tuple[int, ...], ...] — partition of
    #                      range(N), pod p's member client ids
    k_local: int = 2     # per-pod local cluster count

    @property
    def n_pods(self) -> int:
        return len(self.pods)


def hier_params(n_clients: int, n_pods: int, k_local: int = 2,
                pods=None) -> HierParams:
    """Build a validated :class:`HierParams`. Default topology is
    ``n_pods`` contiguous near-equal pods; pass explicit ``pods``
    (iterable of member-id iterables) for arbitrary membership.
    ``k_local`` must fit the smallest pod."""
    if pods is None:
        if not 1 <= n_pods <= n_clients:
            raise ValueError(f"n_pods={n_pods} outside [1, {n_clients}]")
        bounds = np.linspace(0, n_clients, n_pods + 1).astype(int)
        pods = tuple(tuple(range(int(a), int(b)))
                     for a, b in zip(bounds[:-1], bounds[1:]))
    else:
        pods = tuple(tuple(int(i) for i in p) for p in pods)
    seen = sorted(i for p in pods for i in p)
    if seen != list(range(n_clients)):
        raise ValueError("pods must partition range(n_clients) — got "
                         f"{len(seen)} member ids for N={n_clients}")
    smallest = min(len(p) for p in pods)
    if not 1 <= int(k_local) <= smallest:
        raise ValueError(f"k_local={k_local} outside [1, {smallest}] "
                         "(the smallest pod bounds the local cluster "
                         "count)")
    return HierParams(pods=pods, k_local=int(k_local))


@dataclass(frozen=True)
class EngineConfig:
    """Static round configuration (hashable — a jit static argument).

    Holds the model/optimizer *objects*: both are frozen dataclasses of
    pure functions, so configs built from the same instances hash equal
    and share the compiled round program.
    """
    model: Model
    opt: Optimizer
    local_steps: int
    batch_size: int
    lr: float
    aggregation: str = "bso"         # bso | fedavg | none
    n_clusters: int = 3
    p1: float = 0.9
    p2: float = 0.8
    kmeans_iters: int = 20
    use_pallas: bool = False
    reset_opt_each_round: bool = False
    local_unroll: int = 1            # scan unroll of the local phase
                                     # (CPU wants local_steps, TPU 1)


def resolve_local_steps(swarm: SwarmConfig, clients_data,
                        batch_size: int) -> int:
    """The per-round local step count: explicit ``swarm.local_steps``,
    else ``local_epochs`` over the mean clinic size — ONE copy of the
    rule, shared by SwarmTrainer and the baselines' engine slices so
    the two can never silently diverge."""
    if swarm.local_steps is not None:
        return swarm.local_steps
    mean_n = float(np.mean([c["n_train"] for c in clients_data]))
    return max(1, swarm.local_epochs * int(np.ceil(mean_n / batch_size)))


# --------------------------------------------------------------- data layout


def make_batch(cfg: ModelConfig, X, y):
    if cfg.family == "cnn":
        return {"images": jnp.asarray(X), "labels": jnp.asarray(y)}
    return {"tokens": jnp.asarray(X), "labels": jnp.asarray(y)}


def pad_eval_split(X, y, n_to: int):
    """Pad an eval slice to ``n_to`` rows: zero inputs, label=-1 rows
    (the loss/accuracy mask) — the one copy of the masking convention
    shared by the per-client loop and the stacked vmapped eval."""
    pad = n_to - len(y)
    if pad:
        X = np.concatenate([X, np.zeros((pad,) + X.shape[1:], X.dtype)])
        y = np.concatenate([y, -np.ones((pad,) + y.shape[1:], y.dtype)])
    return X, y


def stack_eval_split(cfg: ModelConfig, clients_data, split: str,
                     batch: int = 64):
    """Client-stacked eval data for one split, shaped
    (N, n_batches, batch, ...): every client padded to the largest
    client rounded up to the microbatch size, pad rows label=-1
    (masked)."""
    n_max = max(len(c[split][1]) for c in clients_data)
    n_to = -(-n_max // batch) * batch
    Xs, ys = [], []
    for c in clients_data:
        X, y = pad_eval_split(*c[split], n_to)
        Xs.append(X.reshape((n_to // batch, batch) + X.shape[1:]))
        ys.append(y.reshape((n_to // batch, batch) + y.shape[1:]))
    return make_batch(cfg, np.stack(Xs), np.stack(ys))


def make_swarm_data(cfg: ModelConfig, clients_data, *,
                    eval_batch: int = 64) -> SwarmData:
    """Build the device-resident :class:`SwarmData` from the per-clinic
    host dicts. Train sets are padded to the largest client with
    label=-1 poison rows; ``train_n`` bounds the on-device sampler so
    pads are never drawn."""
    n_max = max(len(c["train"][1]) for c in clients_data)
    Xs, ys = [], []
    for c in clients_data:
        X, y = pad_eval_split(*c["train"], n_max)
        Xs.append(X)
        ys.append(y)
    train = make_batch(cfg, np.stack(Xs), np.stack(ys))
    train_n = jnp.asarray([len(c["train"][1]) for c in clients_data],
                          jnp.int32)
    return SwarmData(train=train, train_n=train_n,
                     val=stack_eval_split(cfg, clients_data, "val",
                                          batch=eval_batch))


def make_bucketed_swarm_data(cfg: ModelConfig, clients_data, *,
                             eval_batch: int = 64, max_buckets: int = 4,
                             strategy: str = "pow2") -> BucketedSwarmData:
    """Build the ragged :class:`BucketedSwarmData` from the per-clinic
    host dicts: clients grouped into size buckets by their train-split
    size (:func:`repro.data.dr.bucket_clients`), each bucket's train
    stack padded only to the bucket's largest client and its eval stack
    built by :func:`stack_eval_split` over the bucket's members (so the
    eval pad also shrinks to the bucket ceiling). ``train_n`` stays in
    global client order — the sampler contract of :class:`SwarmData`.
    """
    from repro.data.dr import bucket_clients
    sizes = [len(c["train"][1]) for c in clients_data]
    groups = bucket_clients(sizes, max_buckets=max_buckets,
                            strategy=strategy)
    trains, vals = [], []
    for ids in groups:
        subset = [clients_data[i] for i in ids]
        n_max = max(len(c["train"][1]) for c in subset)
        Xs, ys = [], []
        for c in subset:
            X, y = pad_eval_split(*c["train"], n_max)
            Xs.append(X)
            ys.append(y)
        trains.append(make_batch(cfg, np.stack(Xs), np.stack(ys)))
        vals.append(stack_eval_split(cfg, subset, "val", batch=eval_batch))
    train_n = jnp.asarray(sizes, jnp.int32)
    return BucketedSwarmData(train=trains, val=vals, train_n=train_n,
                             client_ids=groups)


def pad_fraction(data) -> dict:
    """Host-side pad accounting for either layout: the fraction of
    stored train/eval rows that are padding — the waste metric
    ``BENCH_bucket.json`` quantifies. Returns ``{"train": f, "eval": f,
    "total": f, "stored_rows": n, "real_rows": n}``."""
    if isinstance(data, BucketedSwarmData):
        trains, vals = data.train, data.val
    else:
        trains, vals = (data.train,), (data.val,)
    tr_stored = sum(int(np.prod(jax.tree.leaves(t)[0].shape[:2]))
                    for t in trains)
    tr_real = int(np.sum(np.asarray(data.train_n)))
    ev_stored = ev_real = 0
    for v in vals:
        labels = np.asarray(v["labels"])
        ev_stored += labels.size
        ev_real += int((labels >= 0).sum())
    stored = tr_stored + ev_stored
    real = tr_real + ev_real
    return {"train": 1.0 - tr_real / tr_stored,
            "eval": 1.0 - ev_real / ev_stored,
            "total": 1.0 - real / stored,
            "stored_rows": stored, "real_rows": real}


def make_swarm_state(model: Model, opt: Optimizer, clients_data,
                     key) -> SwarmState:
    """Fresh per-client params/opt state + the round-driving key."""
    init_key, round_key = jax.random.split(key)
    keys = jax.random.split(init_key, len(clients_data))
    params = jax.vmap(model.init)(keys)
    opt_state = jax.vmap(opt.init)(params)
    n_samples = jnp.asarray([c["n_train"] for c in clients_data],
                            jnp.float32)
    return SwarmState(params=params, opt_state=opt_state, key=round_key,
                      round=jnp.zeros((), jnp.int32), n_samples=n_samples,
                      staleness=jnp.zeros((len(clients_data),), jnp.int32))


def make_sweep_state(model: Model, opt: Optimizer, clients_data,
                     keys) -> SwarmState:
    """Method-stacked :class:`SwarmState`: row m is exactly the state
    :func:`make_swarm_state` builds from ``keys[m]``, so a sweep row
    and a serial :func:`run_rounds` call seeded with the same key share
    one PRNG chain (the parity property ``tests/test_sweep.py`` pins).
    """
    states = [make_swarm_state(model, opt, clients_data, k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def make_grid_state(model: Model, opt: Optimizer, clients_data,
                    keys) -> SwarmState:
    """Grid-stacked :class:`SwarmState`: row g is exactly the state
    :func:`make_swarm_state` builds from ``keys[g]`` — the same
    stacking contract as :func:`make_sweep_state`, so a grid row and a
    serial :func:`run_rounds` call seeded with the same key share one
    PRNG chain (the parity property ``tests/test_grid.py`` pins)."""
    return make_sweep_state(model, opt, clients_data, keys)


# -------------------------------------------------------------- round pieces


def sample_local_batch(key, train, train_n, batch_size: int):
    """On-device per-client minibatch: uniform-with-replacement indices
    bounded per client by ``train_n`` (pad rows are unreachable), then a
    vmapped gather — no host loop, no data transfer."""
    N = train_n.shape[0]
    idx = jax.random.randint(key, (N, batch_size), 0, train_n[:, None])
    return jax.tree.map(
        lambda x: jax.vmap(lambda a, i: a[i])(x, idx), train)


def _swarm_batch_indices(key, train_n, batch_size: int, pool):
    """The ONE copy of the method-axis index math: (client, row) pairs
    for one stacked minibatch, layout-independent (both the rectangular
    and the bucketed gathers consume these, so their batches are
    bitwise equal).

    * pool off — the exact draw :func:`sample_local_batch` makes (same
      key, same randint call), so non-centralized sweep rows sample
      bitwise-identical batches to the plain engine path.
    * pool on — every client's slot draws a uniform *global* row id in
      [0, sum(train_n)) (a fold_in'd key keeps the stream disjoint) and
      maps it to (client, row) via the cumulative client sizes: the
      centralized method's "merged client", N replicas wide. Pad rows
      stay unreachable in both branches.
    """
    N = train_n.shape[0]
    own_row = jax.random.randint(key, (N, batch_size), 0, train_n[:, None])
    own_client = jnp.broadcast_to(
        jnp.arange(N, dtype=jnp.int32)[:, None], (N, batch_size))
    cum = jnp.cumsum(train_n)
    g = jax.random.randint(jax.random.fold_in(key, 1), (N, batch_size),
                           0, cum[-1])
    pool_client = jnp.searchsorted(cum, g, side="right").astype(jnp.int32)
    pool_row = g - (cum[pool_client] - train_n[pool_client])
    client = jnp.where(pool, pool_client, own_client)
    row = jnp.where(pool, pool_row, own_row)
    return client, row


def sample_swarm_batch(key, train, train_n, batch_size: int, pool):
    """Method-axis minibatch sampler over the rectangular stack:
    ``pool`` (a traced () bool) selects between the per-client draw and
    the pooled-global draw inside one program (see
    :func:`_swarm_batch_indices`)."""
    client, row = _swarm_batch_indices(key, train_n, batch_size, pool)
    return jax.tree.map(lambda x: x[client, row], train)


def _bucket_maps(client_ids, n_clients: int):
    """Static (bucket, position) lookup per client id — host numpy, so
    bucketed gathers trace to fixed-shape ops."""
    bucket_of = np.zeros(n_clients, np.int32)
    pos_of = np.zeros(n_clients, np.int32)
    for b, ids in enumerate(client_ids):
        for p, c in enumerate(ids):
            bucket_of[c] = b
            pos_of[c] = p
    return bucket_of, pos_of


def _gather_bucketed_rows(data: BucketedSwarmData, client, row):
    """``train[client, row]`` over the bucketed stacks — per-bucket
    gathers select-merged by static bucket membership, so the values
    are bitwise the rectangular gather's (every (client, row) pair maps
    to its bucket's (position, row) slot; out-of-bucket lanes gather a
    safe dummy and are masked out)."""
    N = data.train_n.shape[0]
    bucket_of, pos_of = _bucket_maps(data.client_ids, N)
    b_of = jnp.asarray(bucket_of)[client]
    pos = jnp.asarray(pos_of)[client]
    out = None
    for b, tr in enumerate(data.train):
        in_b = b_of == b
        p = jnp.where(in_b, pos, 0)
        r = jnp.where(in_b, row, 0)
        g = jax.tree.map(lambda x: x[p, r], tr)
        if out is None:
            out = g
        else:
            def sel(new, old):
                m = in_b.reshape(in_b.shape + (1,) * (new.ndim
                                                      - in_b.ndim))
                return jnp.where(m, new, old)
            out = jax.tree.map(sel, g, out)
    return out


def _sample_local_bucketed(key, data: BucketedSwarmData, batch_size: int):
    """Bucketed :func:`sample_local_batch`: the IDENTICAL global index
    draw (same key, same (N, batch) randint over the global-order
    ``train_n`` bounds), gathered per bucket and restored to original
    client order — bitwise the rectangular batch, at bucket-local
    storage cost."""
    N = data.train_n.shape[0]
    idx = jax.random.randint(key, (N, batch_size), 0,
                             data.train_n[:, None])
    parts = []
    for ids, tr in zip(data.client_ids, data.train):
        ids_arr = np.asarray(ids)
        parts.append(jax.tree.map(
            lambda x: jax.vmap(lambda a, i: a[i])(x, idx[ids_arr]), tr))
    cat = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *parts)
    perm = np.concatenate([np.asarray(ids) for ids in data.client_ids])
    inv = np.argsort(perm)
    return jax.tree.map(lambda x: x[inv], cat)


def sample_round_batch(key, data, batch_size: int, pool=None):
    """Layout-dispatching per-step minibatch: the one sampler surface
    :func:`swarm_round` (and the scheduled grid path) calls. ``data``
    is a :class:`SwarmData` or :class:`BucketedSwarmData`; ``pool`` is
    the traced method-axis pooling flag (None = the plain per-client
    path). Both layouts consume the same index draws, so the returned
    batches are bitwise identical."""
    if isinstance(data, BucketedSwarmData):
        if pool is None:
            return _sample_local_bucketed(key, data, batch_size)
        client, row = _swarm_batch_indices(key, data.train_n, batch_size,
                                           pool)
        return _gather_bucketed_rows(data, client, row)
    if pool is None:
        return sample_local_batch(key, data.train, data.train_n,
                                  batch_size)
    return sample_swarm_batch(key, data.train, data.train_n, batch_size,
                              pool)


def local_phase(step, params, opt_state, lr, xs, batch_for_step, *,
                unroll: int = 1, n_active=None, present=None):
    """The shared local-training body of both regimes: a scan of
    vmapped train steps over the client axis.

    ``xs`` is the scan input (sim: per-step sample keys; fleet: step
    indices) and ``batch_for_step(x)`` materialises that step's stacked
    (N, B, ...) batch — sampling a fresh gather in the sim regime,
    slicing the uploaded round batch in the fleet regime.

    ``n_active`` (a traced () int32, or None) is the grid engine's
    local-step override: every static step still computes (fixed
    shapes, unconditional key consumption — all grid rows share one
    program) but steps ``>= n_active`` leave params/opt state
    untouched, so applying all steps is bitwise the plain path.

    ``present`` (a traced (N,) participation mask, or None) is the
    churn axis's local-phase gate: every client still computes every
    step (fixed shapes, unconditional key consumption — all churn
    schedules share one program) but absent clients' params/opt state
    are where-selected back, a per-client masked no-op, and the step
    loss averages over present clients only. All-ones is bitwise the
    unmasked path (``where(True, ...)`` identity; the masked loss mean
    reduces over the identical addends).

    ``unroll`` trades compile time for loop overhead: XLA's CPU backend
    executes ops inside a while body markedly slower than the same ops
    unrolled (~2x on convs), so CPU benchmarking wants
    ``unroll=len(xs)``; TPU and large models want the rolled default."""
    vstep = jax.vmap(step, in_axes=(0, 0, 0, None))
    if present is not None:
        present = jnp.asarray(present, bool)

        def sel_client(new, old):
            m = present.reshape(present.shape
                                + (1,) * (new.ndim - present.ndim))
            return jnp.where(m, new, old)

    def body(carry, ix):
        i, x = ix
        p, o = carry
        p2, o2, m = vstep(p, o, batch_for_step(x), lr)
        if n_active is not None:
            on = i < n_active
            p2 = jax.tree.map(lambda new, old: jnp.where(on, new, old),
                              p2, p)
            o2 = jax.tree.map(lambda new, old: jnp.where(on, new, old),
                              o2, o)
        if present is None:
            loss = jnp.mean(m["loss"])
        else:
            p2 = jax.tree.map(sel_client, p2, p)
            o2 = jax.tree.map(sel_client, o2, o)
            pf = present.astype(jnp.float32)
            # reciprocal-multiply, not divide: XLA strength-reduces
            # jnp.mean's constant denominator to a reciprocal multiply,
            # so the all-ones masked mean is only bitwise-equal to
            # jnp.mean if it rounds through the same reciprocal
            loss = (jnp.sum(m["loss"] * pf)
                    * (1.0 / jnp.maximum(jnp.sum(pf), 1.0)))
        return (p2, o2), loss

    n_steps = jax.tree.leaves(xs)[0].shape[0]
    (params, opt_state), losses = jax.lax.scan(
        body, (params, opt_state), (jnp.arange(n_steps), xs), unroll=unroll)
    return params, opt_state, losses


def make_client_eval(model: Model):
    """Per-client masked accuracy over stacked (N, n_batches, batch, ..)
    eval data — one vmapped program, scanning fixed microbatches so the
    activation footprint stays O(N * batch) regardless of split size."""
    eval_step = make_eval_step(model)

    def client_eval(params, batches):
        def one(carry, bt):
            hits, tot = carry
            m = eval_step(params, bt)
            valid = jnp.sum(bt["labels"] >= 0).astype(jnp.float32)
            return (hits + m["acc"] * valid, tot + valid), None

        (hits, tot), _ = jax.lax.scan(
            one, (jnp.float32(0.0), jnp.float32(0.0)), batches)
        return hits / jnp.maximum(tot, 1.0)

    return jax.vmap(client_eval)


def eval_swarm(model: Model, params, data):
    """Layout-dispatching per-client val accuracy — the masked segment
    reduction over whichever stacks ``data`` carries.

    Rectangular: the one vmapped :func:`make_client_eval` program.
    Bucketed: one fixed-shape vmapped eval per bucket (same static-
    shape discipline as :func:`run_grid` — equal bucket signatures
    share the trace), client accuracies scattered back to global
    order. BITWISE the rectangular result: a bucket's stack is a
    microbatch-prefix of the rectangular stack, and every dropped
    all-pad microbatch contributed exactly +0.0 to the (hits, total)
    accumulator (``accuracy`` masks label=-1 rows and divides by
    ``max(valid, 1)``).
    """
    ev = make_client_eval(model)
    if isinstance(data, BucketedSwarmData):
        N = data.train_n.shape[0]
        acc = jnp.zeros((N,), jnp.float32)
        for ids, val_b in zip(data.client_ids, data.val):
            ids_arr = np.asarray(ids)
            sub = jax.tree.map(lambda x: x[ids_arr], params)
            acc = acc.at[ids_arr].set(ev(sub, val_b))
        return acc
    return ev(params, data.val)


# ---------------------------------------------------------------- the round


def _coordinate_and_aggregate(params, opt_state, val, n_samples,
                              cfg: "EngineConfig", masks: MethodParams,
                              grid, k_kmeans, k_bso, present=None,
                              eff_w=None):
    """The method/grid-axis coordinator + Eq. 2 tail of
    :func:`swarm_round`, factored out so the sorted-schedule grid path
    can vmap exactly the same ops over its rows: distribution stats →
    masked k-means → brain storm → traced-mask selection → N-segment
    ``cluster_fedavg``. Returns ``(params, opt_state, assignments,
    centers, n_replaced, n_swapped)``.

    ``present`` / ``eff_w`` (both None, or both set) are the churn
    axis: absent clients are masked out of the k-means stats matrix
    (an all-absent cluster rides its empty reseed), their brain-storm
    scores are the recomputed scores of their stale params (the
    deterministic equivalent of a server-cached last report), and
    Eq. 2 runs the masked variant — effective weights ``eff_w``
    (zero or staleness-decayed for absent clients), aggregates
    delivered to present clients only."""
    N = n_samples.shape[0]
    zero = jnp.zeros((), jnp.int32)
    # the method/grid axis: one program, per-row traced masks. The
    # aggregation segment count is N so every base_assign plan
    # (arange = identity, zeros = global) shares the bso layout.
    # cfg.n_clusters is the static pad k_max; a grid row masks the
    # coordinator down to its traced point.n_clusters.
    k = cfg.n_clusters
    assert k <= N, "method axis needs n_clusters <= n_clients"
    k_act = None if grid is None else grid.n_clusters
    p1 = cfg.p1 if grid is None else grid.p1
    p2 = cfg.p2 if grid is None else grid.p2
    feats = swarm_distribution_matrix(params, use_pallas=cfg.use_pallas)
    _, a0 = kmeans(k_kmeans, feats, k=k, iters=cfg.kmeans_iters,
                   use_pallas=cfg.use_pallas, k_active=k_act,
                   mask=present)
    bsa_a, bsa_c, n_rep, n_swap = brain_storm_jax(
        k_bso, a0, val, k, p1, p2)
    use = masks.use_coord
    assignments = jnp.where(use, bsa_a, masks.base_assign)
    centers = jnp.where(use, bsa_c, -1)
    n_rep = jnp.where(use, n_rep, zero)
    n_swap = jnp.where(use, n_swap, zero)
    if present is None:
        params = cluster_fedavg(params, assignments, n_samples, k=N)
    else:
        params = cluster_fedavg_masked(params, assignments, eff_w,
                                       present, k=N)
    if cfg.reset_opt_each_round:
        new_opt = jax.vmap(cfg.opt.init)(params)
        if present is None:
            opt_state = new_opt
        else:
            def sel(new, old):
                m = present.reshape(present.shape
                                    + (1,) * (new.ndim - 1))
                return jnp.where(m, new, old)
            opt_state = jax.tree.map(sel, new_opt, opt_state)
    return params, opt_state, assignments, centers, n_rep, n_swap


def pod_summaries(feats, val, weights, present, k_local: int,
                  kmeans_iters: int, key, pods, *,
                  use_pallas: bool = False):
    """The pod tier of the hierarchical coordinator: per-pod local
    k-means over member stats, reduced to O(pods * k_local) summaries.

    ``pods`` is the static membership (tuple of member-id tuples —
    :attr:`HierParams.pods`); the loop over pods is a static python
    loop, so unequal pods trace to their own fixed shapes inside the
    ONE program. Pod ``p`` clusters its members' ``feats`` rows with
    key ``fold_in(key, p)`` (mask = the members' ``present`` slice, so
    churn composes exactly as in the flat path), then segment-sums its
    members into per-pod-cluster summaries.

    Returns ``(centroids (P*kl, F), counts (P*kl,), wsums (P*kl,),
    valsums (P*kl,), pc_of (N,))`` where ``counts`` are *present*
    member counts, ``wsums`` sum the members' effective Eq. 2 weights
    (``weights``), ``valsums`` their val scores, and ``pc_of`` maps
    each client to its global pod-cluster row ``p * k_local + a_local``
    (absent clients included — their membership feeds the
    staleness-weighted Eq. 2, mirroring the masked flat k-means).

    This is exactly the payload the fleet surface uploads to the host
    coordinator — the O(pods) traffic claim of ``BENCH_hier.json``.
    """
    N = val.shape[0]
    kl = int(k_local)
    cents, cnts, wss, vss = [], [], [], []
    pc_of = jnp.zeros((N,), jnp.int32)
    for p, ids in enumerate(pods):
        idx = np.asarray(ids)
        f_p = feats[idx]
        m_p = None if present is None else present[idx]
        C_p, a_p = kmeans(jax.random.fold_in(key, p), f_p, k=kl,
                          iters=kmeans_iters, use_pallas=use_pallas,
                          mask=m_p)
        w_p = (jnp.ones((len(ids),), feats.dtype) if m_p is None
               else m_p.astype(feats.dtype))
        cents.append(C_p)
        cnts.append(jax.ops.segment_sum(w_p, a_p, kl))
        wss.append(jax.ops.segment_sum(weights[idx] * w_p, a_p, kl))
        vss.append(jax.ops.segment_sum(val[idx] * w_p, a_p, kl))
        pc_of = pc_of.at[idx].set(p * kl + a_p.astype(jnp.int32))
    return (jnp.concatenate(cents, axis=0), jnp.concatenate(cnts),
            jnp.concatenate(wss), jnp.concatenate(vss), pc_of)


def global_tier(key_kmeans, k_bso, centroids, counts, valsums, *,
                k: int, kmeans_iters: int, p1, p2,
                use_pallas: bool = False):
    """The global tier of the hierarchical coordinator, over pod
    summaries instead of clients: member-count-weighted k-means
    (the centroid-input mode of :func:`repro.core.kmeans.kmeans`) +
    ``brain_storm_jax`` ranking pod-cluster mean val scores.

    Empty pod-clusters (``counts == 0`` — a pod's k-means left a slot
    unoccupied, or every member was absent) carry zero k-means weight
    and a val score of -1.0, so they never win a best-val center and
    their occasional selection as a random replacement target moves no
    real clients (they have none) — the same inertness contract the
    flat path's pad clusters rely on. The brain storm's swap
    granularity here is a whole pod-cluster: one swap moves every
    member of the summary row, the price of ranking O(pods) rows
    instead of O(clients).

    Returns ``(g (P*kl,) pod-cluster -> global cluster, centers_s (k,)
    best-val summary rows, n_replaced, n_swapped)``.
    """
    occupied = counts > 0
    val_means = jnp.where(occupied,
                          valsums / jnp.maximum(counts, 1e-9), -1.0)
    _, g0 = kmeans(key_kmeans, centroids, k=k, iters=kmeans_iters,
                   use_pallas=use_pallas, weights=counts)
    g, centers_s, n_rep, n_swap = brain_storm_jax(k_bso, g0, val_means,
                                                  k, p1, p2)
    return g, centers_s, n_rep, n_swap


def _hier_coordinate_and_aggregate(params, opt_state, val, n_samples,
                                   cfg: "EngineConfig", hier: HierParams,
                                   k_kmeans, k_bso, present=None,
                                   eff_w=None):
    """The two-tier coordinator + Eq. 2 tail of :func:`swarm_round` —
    the hierarchical sibling of :func:`_coordinate_and_aggregate`
    (plain bso path only; the method/grid axes keep the flat
    coordinator). Pod tier -> global tier -> composed client
    assignments ``g[pc_of]`` -> the unchanged N-segment Eq. 2."""
    N = n_samples.shape[0]
    k = cfg.n_clusters
    P, kl = hier.n_pods, hier.k_local
    assert k <= P * kl, (
        f"hier global tier needs n_clusters={k} <= n_pods*k_local="
        f"{P * kl} summary rows")
    feats = swarm_distribution_matrix(params, use_pallas=cfg.use_pallas)
    # disjoint key streams for the pod tier and the global tier (the
    # flat path spends k_kmeans directly; fold_in(k_pods, p) per pod)
    k_pods, k_global = jax.random.split(k_kmeans)
    w = n_samples if eff_w is None else eff_w
    centroids, counts, wsums, valsums, pc_of = pod_summaries(
        feats, val, w, present, kl, cfg.kmeans_iters, k_pods, hier.pods,
        use_pallas=cfg.use_pallas)
    g, centers_s, n_rep, n_swap = global_tier(
        k_global, k_bso, centroids, counts, valsums, k=k,
        kmeans_iters=cfg.kmeans_iters, p1=cfg.p1, p2=cfg.p2,
        use_pallas=cfg.use_pallas)
    assignments = g[pc_of]
    # RoundMetrics centers want client ids: a summary-row center maps
    # to its best-val present member (-1 when the row is empty — the
    # same "no center" convention the method axis uses)
    member = pc_of[None, :] == jnp.arange(P * kl)[:, None]   # (S, N)
    if present is not None:
        member = member & present[None, :]
    score = jnp.where(member, val[None, :], -jnp.inf)
    rep = jnp.where(member.any(axis=1),
                    jnp.argmax(score, axis=1).astype(jnp.int32), -1)
    centers = jnp.where(centers_s >= 0,
                        rep[jnp.clip(centers_s, 0, P * kl - 1)], -1)
    if present is None:
        params = cluster_fedavg(params, assignments, n_samples, k=N)
    else:
        params = cluster_fedavg_masked(params, assignments, eff_w,
                                       present, k=N)
    if cfg.reset_opt_each_round:
        new_opt = jax.vmap(cfg.opt.init)(params)
        if present is None:
            opt_state = new_opt
        else:
            def sel(new, old):
                m = present.reshape(present.shape
                                    + (1,) * (new.ndim - 1))
                return jnp.where(m, new, old)
            opt_state = jax.tree.map(sel, new_opt, opt_state)
    return params, opt_state, assignments, centers, n_rep, n_swap


#: fold_in tag deriving the churn Bernoulli key from the round's local
#: sampling key — fold_in does not consume the split stream, so the
#: no-churn key discipline (and with it bitwise parity) is untouched.
_CHURN_KEY_TAG = 0x0C


def swarm_round(state: SwarmState, data: SwarmData,
                cfg: EngineConfig, method: MethodParams = None,
                churn: ChurnParams = None, hier: HierParams = None):
    """One full BSO-SL round as a pure function — local steps, eval,
    distribution upload, k-means, brain storm, Eq. 2 aggregation.

    Jit it with ``cfg`` static (see :data:`jit_swarm_round`) and the
    entire round is one device program; scan it (:func:`run_rounds`)
    and a whole training run is one program.

    ``method`` switches the body onto a traced axis: the coordinator
    (stats + k-means + brain storm) always runs, but the traced masks
    pick which assignments aggregate and whether sampling pools — so
    the one lowered program is vmappable over stacked rows. It accepts

    * a :class:`MethodParams` — the Table-II method axis
      (:func:`run_sweep` vmaps this),
    * a :class:`GridPoint` — the hyper-parameter grid axis: the method
      masks plus traced k / p1 / p2 / local-step / lr overrides of the
      config statics (:func:`run_grid` vmaps this; the statics are the
      row maxima — see :class:`GridPoint`),
    * ``None`` — the static ``cfg.aggregation`` branches keep the
      leaner single-method programs (``none`` skips the coordinator
      entirely).

    ``churn`` threads the scenario axis (:class:`ChurnParams`) through
    any of those paths; a :class:`GridPoint` carrying a churn row is
    picked up automatically. Absent clients run masked no-op local
    steps, keep their stale params through Eq. 2, and are excluded
    from the k-means stats; their staleness counters
    (:attr:`SwarmState.staleness`) increment, and participation resets
    them to 0. An all-ones mask (or ``dropout=0``) is bitwise the
    churn-free round — the parity anchor ``tests/test_churn.py`` pins.

    ``hier`` (a STATIC :class:`HierParams`, or None) switches the
    coordinator onto the two-tier path: per-pod local k-means over
    member stats, a member-count-weighted global k-means + brain storm
    over the O(pods * k_local) pod-cluster summaries, composed client
    assignments ``g[pod * k_local + a_local]``, Eq. 2 unchanged. Plain
    bso path only (the method/grid axes keep the flat coordinator —
    their masks select *against* the flat assignments); composes with
    ``churn`` (absent clients are masked out of their pod's k-means
    and carry staleness-decayed Eq. 2 weight, as in the flat path).
    ``hier=None`` is the flat path untouched and a single-pod
    ``HierParams`` routes to the flat coordinator verbatim (see
    :class:`HierParams`) — both bitwise, ``tests/test_hier.py`` pins.
    """
    model, opt = cfg.model, cfg.opt
    step = make_train_step(model, opt)
    next_key, k_local, k_kmeans, k_bso = jax.random.split(state.key, 4)

    grid = method if isinstance(method, GridPoint) else None
    masks = grid.method if grid is not None else method
    lr = cfg.lr if grid is None else grid.lr
    if churn is None and grid is not None:
        churn = grid.churn
    if hier is not None:
        if masks is not None:
            raise ValueError(
                "hier composes with the plain path only — the "
                "method/grid axes mask against the flat coordinator's "
                "assignments; run hierarchical rows as separate "
                "run_rounds fits")
        if cfg.aggregation != "bso":
            raise ValueError(
                f"hier needs cfg.aggregation='bso' (got "
                f"{cfg.aggregation!r}) — fedavg/none have no "
                "coordinator to shard")
        if hier.n_pods == 1:
            # one pod = the whole swarm: the pod-local clustering IS
            # the global clustering, so the flat coordinator is the
            # degenerate two-tier program — route to it verbatim
            # (bitwise, pinned in tests/test_hier.py)
            hier = None

    # --- churn axis: this round's participation mask + staleness
    N = data.train_n.shape[0]
    present = eff_w = staleness = None
    if churn is not None:
        if state.staleness is None:
            raise ValueError(
                "the churn axis needs SwarmState.staleness — rebuild "
                "the state with make_swarm_state (or _replace a zeros "
                "(N,) int32 field onto a pre-churn state)")
        if churn.mask is not None:
            present = jnp.asarray(churn.mask, bool)
            if present.ndim != 1:
                raise ValueError(
                    "swarm_round wants a per-round (N,) churn mask; "
                    "run_rounds scans (rounds, N) schedules")
        else:
            u = jax.random.uniform(
                jax.random.fold_in(k_local, _CHURN_KEY_TAG), (N,))
            present = u >= churn.dropout
        staleness = jnp.where(present, 0, state.staleness + 1)
        # effective Eq. 2 weight |D_h| * decay^staleness: present
        # clients multiply by decay^0 == 1.0 (bitwise |D_h|), hard
        # masking (decay=0) zeroes every absent client (0^k == 0, k>0)
        eff_w = state.n_samples * jnp.power(
            churn.stale_decay, staleness.astype(jnp.float32))

    # --- local phase: cfg.local_steps of on-device-sampled SGD (grid
    # rows apply only the first grid.local_steps of them; absent
    # churn-axis clients apply none)
    sample_keys = jax.random.split(k_local, cfg.local_steps)
    if masks is None:
        batch_for_step = lambda kt: sample_round_batch(
            kt, data, cfg.batch_size)
    else:
        batch_for_step = lambda kt: sample_round_batch(
            kt, data, cfg.batch_size, masks.pool_data)
    params, opt_state, losses = local_phase(
        step, state.params, state.opt_state, lr, sample_keys,
        batch_for_step, unroll=cfg.local_unroll,
        n_active=None if grid is None else grid.local_steps,
        present=present)
    # the last *applied* step's loss (grid rows stop early)
    train_loss = losses[-1] if grid is None else losses[grid.local_steps - 1]

    # --- eval: per-client val accuracy (shared within clusters, §III.C).
    # Absent clients are scored on their stale params — eval is
    # deterministic in (params, val split), so this IS the score the
    # coordinator cached at their last participation.
    val = eval_swarm(model, params, data)

    # --- coordinator + aggregation
    zero = jnp.zeros((), jnp.int32)
    if masks is not None:
        (params, opt_state, assignments, centers, n_rep,
         n_swap) = _coordinate_and_aggregate(
            params, opt_state, val, state.n_samples, cfg, masks, grid,
            k_kmeans, k_bso, present=present, eff_w=eff_w)
    elif cfg.aggregation == "none":
        assignments = jnp.zeros((N,), jnp.int32)
        centers = jnp.zeros((0,), jnp.int32)
        n_rep = n_swap = zero
    elif hier is not None:
        if len(hier.pods[0]) + sum(len(p) for p in hier.pods[1:]) != N:
            raise ValueError(
                f"hier pods cover {sum(len(p) for p in hier.pods)} "
                f"clients but the swarm has {N}")
        (params, opt_state, assignments, centers, n_rep,
         n_swap) = _hier_coordinate_and_aggregate(
            params, opt_state, val, state.n_samples, cfg, hier,
            k_kmeans, k_bso, present=present, eff_w=eff_w)
    else:
        if cfg.aggregation == "fedavg":
            k = 1
            assignments = jnp.zeros((N,), jnp.int32)
            centers = jnp.argmax(val)[None].astype(jnp.int32)
            n_rep = n_swap = zero
        else:
            k = cfg.n_clusters
            feats = swarm_distribution_matrix(params,
                                              use_pallas=cfg.use_pallas)
            _, a0 = kmeans(k_kmeans, feats, k=k, iters=cfg.kmeans_iters,
                           use_pallas=cfg.use_pallas, mask=present)
            assignments, centers, n_rep, n_swap = brain_storm_jax(
                k_bso, a0, val, k, cfg.p1, cfg.p2)
        if present is None:
            params = cluster_fedavg(params, assignments, state.n_samples,
                                    k=k)
        else:
            params = cluster_fedavg_masked(params, assignments, eff_w,
                                           present, k=k)
        if cfg.reset_opt_each_round:
            new_opt = jax.vmap(opt.init)(params)
            if present is None:
                opt_state = new_opt
            else:
                def sel(new, old):
                    m = present.reshape(present.shape
                                        + (1,) * (new.ndim - 1))
                    return jnp.where(m, new, old)
                opt_state = jax.tree.map(sel, new_opt, opt_state)

    new_state = SwarmState(params=params, opt_state=opt_state, key=next_key,
                           round=state.round + 1, n_samples=state.n_samples,
                           staleness=(staleness if churn is not None
                                      else state.staleness))
    metrics = RoundMetrics(mean_val_acc=jnp.mean(val), val_acc=val,
                           train_loss=train_loss, assignments=assignments,
                           centers=centers, n_replaced=n_rep,
                           n_swapped=n_swap,
                           present=(present if present is not None
                                    else jnp.ones((N,), bool)))
    return new_state, metrics


def run_rounds(state: SwarmState, data: SwarmData, cfg: EngineConfig,
               rounds: int, method: MethodParams = None,
               churn: ChurnParams = None, hier: HierParams = None):
    """Scan :func:`swarm_round` over ``rounds``: the whole multi-round
    fit as ONE device program. Metrics gain a leading (rounds,) axis.
    ``method`` threads a :class:`MethodParams` (Table-II method axis)
    or :class:`GridPoint` (hyper-parameter grid row) through every
    round; ``churn`` (or the grid row's own churn) threads the
    scenario axis — a (rounds, N) explicit mask schedule is scanned
    one row per round, everything else is closed over per round.
    ``hier`` (static) threads the two-tier coordinator topology
    through every round (see :func:`swarm_round`)."""
    if churn is None and isinstance(method, GridPoint):
        churn = method.churn
    if churn is not None and churn.mask is not None \
            and churn.mask.ndim == 2:
        if churn.mask.shape[0] != rounds:
            raise ValueError(
                f"churn mask schedule has {churn.mask.shape[0]} rows "
                f"for rounds={rounds}")

        def sched_body(s, mk):
            return swarm_round(s, data, cfg, method,
                               churn._replace(mask=mk), hier)

        return jax.lax.scan(sched_body, state, churn.mask, length=rounds)

    def body(s, _):
        return swarm_round(s, data, cfg, method, churn, hier)

    return jax.lax.scan(body, state, None, length=rounds)


def run_sweep(state: SwarmState, data: SwarmData, cfg: EngineConfig,
              sweep: MethodParams, rounds: int):
    """The whole paper-table sweep as ONE device program.

    ``state`` is method-stacked (:func:`make_sweep_state`), ``sweep``
    is the stacked :class:`MethodParams` (:func:`make_sweep_config`);
    both carry a leading (M,) axis. The single :class:`SwarmData` is
    closed over un-vmapped, so every method reads the same device
    buffers. Row m is exactly ``run_rounds(state[m], data, cfg,
    rounds, sweep[m])`` — the parity contract ``tests/test_sweep.py``
    asserts against the serial ``run_method`` slice.
    """
    def one(s, m):
        return run_rounds(s, data, cfg, rounds, m)

    return jax.vmap(one)(state, sweep)


def run_grid(state: SwarmState, data: SwarmData, cfg: EngineConfig,
             grid: GridPoint, rounds: int, schedule=None):
    """A whole hyper-parameter ablation as ONE device program.

    ``state`` is grid-stacked (:func:`make_grid_state`), ``grid`` is
    the stacked :class:`GridPoint` (:func:`make_grid_config`); both
    carry a leading (G,) axis. The single :class:`SwarmData` (or
    :class:`BucketedSwarmData`) is closed over un-vmapped, so every
    grid point reads the same device buffers — |grid| serial fits
    collapse into one vmapped executable whose static shapes come from
    the row maxima in ``cfg``. Row g is exactly ``run_rounds(state[g],
    data, cfg, rounds, grid[g])`` — the parity contract
    ``tests/test_grid.py`` asserts against the serial
    ``baselines.run_grid_point`` slice.

    ``schedule`` (a STATIC tuple of per-row applied step counts,
    mirroring each row's traced ``grid.local_steps``) switches the
    local phase onto the sorted scan schedule
    (:func:`_run_grid_scheduled`): rows with small step budgets exit
    the scan early instead of paying ``cfg.local_steps`` masked no-op
    steps. Still ONE program; parity with the masked path is allclose
    (~1 ulp — see :func:`_run_grid_scheduled`).
    """
    if schedule is not None:
        if grid.churn is not None:
            raise ValueError(
                "the sorted local-steps schedule does not support churn "
                "rows (its prefix segments assume every row trains every "
                "client); pass schedule=None — churn grids ride the "
                "masked path")
        return _run_grid_scheduled(state, data, cfg, grid, rounds,
                                   tuple(schedule))

    def one(s, g):
        return run_rounds(s, data, cfg, rounds, g)

    return jax.vmap(one)(state, grid)


def _run_grid_scheduled(state: SwarmState, data, cfg: EngineConfig,
                        grid: GridPoint, rounds: int, schedule: tuple):
    """:func:`run_grid` with a ``local_steps``-sorted scan schedule.

    The masked path pays ``G x cfg.local_steps`` train steps per round
    — rows with ``local_steps < max`` compute every step and discard
    the tail as masked no-ops (a vmap lane cannot exit a scan early).
    Here rows are pre-sorted by DESCENDING static step count and the
    local phase runs as static prefix segments: between the distinct
    step counts ``s_1 < s_2 < ...`` of the schedule, only the prefix of
    rows still inside their budget scans on (total row-steps =
    ``sum(schedule)`` instead of ``G * max``). Everything the per-row
    :func:`swarm_round` would compute is replicated — the 4-way key
    split, the per-step sample keys, the layout-dispatched sampler,
    eval, and the factored :func:`_coordinate_and_aggregate` — and a
    skipped step's masked no-op never touched params, so every applied
    step consumes identical keys and batches. Parity with the masked
    path is ALLCLOSE (~1 ulp, ``tests/test_grid.py``), not bitwise: a
    prefix segment batches the train step over ``g < G`` rows, and
    XLA's conv kernels reduce in a lane-width-dependent order — only
    rows that never leave the full-width segment match bit for bit.

    ``schedule`` must be static (it shapes the program) and must equal
    the traced per-row ``grid.local_steps`` values — the loss gather at
    ``local_steps - 1`` reads only computed slots when they agree.
    ``run_grid_table`` derives it from the row specs automatically.
    """
    G = len(schedule)
    for s in schedule:
        if not 1 <= int(s) <= cfg.local_steps:
            raise ValueError(f"schedule entry {s} outside "
                             f"[1, {cfg.local_steps}]")
    order = np.argsort(-np.asarray(schedule), kind="stable")
    inv = np.argsort(order)
    steps_sorted = [int(schedule[i]) for i in order]
    # static prefix segments: during steps [a, b), the first g rows
    # (sorted desc) are still inside their budget
    segs = []
    prev = 0
    for s in sorted(set(steps_sorted)):
        segs.append((prev, s, sum(1 for t in steps_sorted if t > prev)))
        prev = s

    state = jax.tree.map(lambda x: x[order], state)
    grid = jax.tree.map(lambda x: x[order], grid)
    model, opt = cfg.model, cfg.opt
    step = make_train_step(model, opt)
    vstep = jax.vmap(step, in_axes=(0, 0, 0, None))     # over clients
    gstep = jax.vmap(vstep, in_axes=(0, 0, 0, 0))       # over grid rows

    def round_body(st, _):
        # per-row key discipline, replicated from swarm_round exactly
        keys4 = jax.vmap(lambda kk: jax.random.split(kk, 4))(st.key)
        next_key, k_local, k_kmeans, k_bso = (keys4[:, i]
                                              for i in range(4))
        sample_keys = jax.vmap(
            lambda kk: jax.random.split(kk, cfg.local_steps))(k_local)
        params, opt_state = st.params, st.opt_state
        losses = jnp.zeros((G, cfg.local_steps), jnp.float32)

        for a, b, g in segs:
            p_g = jax.tree.map(lambda x: x[:g], params)
            o_g = jax.tree.map(lambda x: x[:g], opt_state)
            lr_g, pool_g = grid.lr[:g], grid.method.pool_data[:g]
            kts = jnp.swapaxes(sample_keys[:g, a:b], 0, 1)

            def seg_body(carry, kt, pool_g=pool_g, lr_g=lr_g):
                p, o = carry
                batch = jax.vmap(lambda kk, pl: sample_round_batch(
                    kk, data, cfg.batch_size, pl))(kt, pool_g)
                p2, o2, m = gstep(p, o, batch, lr_g)
                return (p2, o2), jnp.mean(m["loss"], axis=-1)

            (p_g, o_g), seg_losses = jax.lax.scan(
                seg_body, (p_g, o_g), kts, unroll=cfg.local_unroll)
            params = jax.tree.map(
                lambda sg, full: jnp.concatenate([sg, full[g:]], axis=0),
                p_g, params)
            opt_state = jax.tree.map(
                lambda sg, full: jnp.concatenate([sg, full[g:]], axis=0),
                o_g, opt_state)
            losses = losses.at[:g, a:b].set(jnp.swapaxes(seg_losses,
                                                         0, 1))

        train_loss = jnp.take_along_axis(
            losses, grid.local_steps[:, None] - 1, axis=1)[:, 0]
        val = jax.vmap(lambda p: eval_swarm(model, p, data))(params)
        (params, opt_state, assignments, centers, n_rep,
         n_swap) = jax.vmap(
            lambda p, o, v, ns, gg, kk, kb: _coordinate_and_aggregate(
                p, o, v, ns, cfg, gg.method, gg, kk, kb)
        )(params, opt_state, val, st.n_samples, grid, k_kmeans, k_bso)
        new_state = SwarmState(params=params, opt_state=opt_state,
                               key=next_key, round=st.round + 1,
                               n_samples=st.n_samples,
                               staleness=st.staleness)
        metrics = RoundMetrics(
            mean_val_acc=jnp.mean(val, axis=1), val_acc=val,
            train_loss=train_loss, assignments=assignments,
            centers=centers, n_replaced=n_rep, n_swapped=n_swap,
            present=jnp.ones(val.shape, bool))
        return new_state, metrics

    state, ms = jax.lax.scan(round_body, state, None, length=rounds)
    # (rounds, G, ...) -> (G, rounds, ...), then undo the sort
    ms = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1)[inv], ms)
    state = jax.tree.map(lambda x: x[inv], state)
    return state, ms


# module-level jitted entry points: the cache is shared across every
# host wrapper holding an equal EngineConfig (state buffers donated —
# each round updates the swarm in place)
jit_swarm_round = jax.jit(swarm_round, static_argnames=("cfg", "hier"),
                          donate_argnums=(0,))
jit_run_rounds = jax.jit(run_rounds,
                         static_argnames=("cfg", "rounds", "hier"),
                         donate_argnums=(0,))
jit_run_sweep = jax.jit(run_sweep, static_argnames=("cfg", "rounds"),
                        donate_argnums=(0,))
jit_run_grid = jax.jit(run_grid,
                       static_argnames=("cfg", "rounds", "schedule"),
                       donate_argnums=(0,))


# ------------------------------------------------------------- fleet regime


class FleetRoundOut(NamedTuple):
    """The tiny per-round outputs the fleet driver pulls to host.

    Everything here is O(clients): the whole device->host traffic of a
    fleet round is this pytree — the models themselves never leave the
    mesh (paper §III.B's communication-efficiency claim).
    """
    stats: Any        # (N, 2*#tensors) distribution-stat upload of the
                      #   post-local-phase params (§III.B)
    val_acc: Any      # (N,) per-client masked val accuracy — the scores
                      #   the brain-storm step ranks (§III.C step 1)
    train_loss: Any   # () mean loss of the last local step


class HierRoundOut(NamedTuple):
    """The per-round outputs of the HIERARCHICAL fleet surface.

    The flat :class:`FleetRoundOut` is O(clients); this one is O(pods):
    the round program runs each pod's local k-means on-mesh and only
    the ``S = n_pods * k_local`` pod-cluster summaries cross to the
    host (the two-tier coordinator's entire upload — ``BENCH_hier.json``
    measures exactly these arrays' bytes). ``a_local`` is (N,) but is
    NOT part of the upload: the driver feeds it back device-to-device
    as the next round's ``a_prev`` operand without ever materialising
    it on host.
    """
    centroids: Any    # (S, 2*#tensors) pod-cluster stat centroids
    counts: Any       # (S,) reporting-member counts (the global tier's
                      #   k-means weights)
    wsums: Any        # (S,) summed member Eq. 2 weights
    valsums: Any      # (S,) summed member val accuracies (mean = the
                      #   score the global brain storm ranks)
    a_local: Any      # (N,) int32 global pod-cluster index of each
                      #   client (pod * k_local + local assignment) —
                      #   device-resident feedback, never pulled
    mean_val: Any     # () swarm-mean val accuracy (all clients) — the
                      #   O(1) trajectory metric the driver logs
    train_loss: Any   # () mean loss of the last local step


def make_fleet_round(model: Model, opt: Optimizer, k: int,
                     n_local_steps: int = 1, *, use_pallas: bool = False,
                     with_eval: bool = False, with_loss: bool = False,
                     axis_name: str = None, with_churn: bool = False,
                     hier_k_local: int = 0, hier_pods: int = 0,
                     hier_kmeans_iters: int = 20):
    """Fleet round built from the same body as :func:`swarm_round`,
    reordered so a multi-round driver can close the coordinator loop
    with NO extra program: first Eq. 2 ``cluster_fedavg`` applies the
    *incoming* coordinator decision (``clusters`` computed on host from
    the previous round's stat upload; XLA SPMD inserts the cross-pod
    collectives), then the shared :func:`local_phase` runs (per-step
    microbatch slices of the uploaded round batch instead of on-device
    sampling), then the distribution-stat upload is computed *inside*
    the program — the ``param_stats_batched`` kernel under
    ``use_pallas``, the jnp oracle otherwise — so the O(#tensors) stats
    ride the same dispatch as the round step.

    Only the O(clients) coordinator decision (k-means + brain storm)
    stays host-side, matching the paper's neighbour-assignment server:
    the driver turns round r's returned ``stats`` into round r+1's
    ``clusters`` (see ``repro.launch.fleet_driver``). Seeding round 0
    with ``singleton_assignments(N)`` makes its aggregation the bitwise
    identity, so R driver rounds execute exactly the sim engine's
    protocol sequence (train -> eval -> stats -> coordinator -> Eq. 2,
    R times) with the final Eq. 2 left pending on the mesh — the
    aggregate-first rotation only moves the round boundary, not the
    order of operations.

    ``with_eval=False`` returns
    ``round_step(sparams, sopt, batch, lr, clusters, weights)
    -> (sparams, sopt, stats)`` — the dry-run lowering surface.
    ``with_eval=True`` adds the stacked eval batches argument
    (:func:`stack_eval_split` layout) and returns the full driver
    surface ``round_step(sparams, sopt, batch, val, lr, clusters,
    weights) -> (sparams, sopt, FleetRoundOut)`` — the per-client val
    accuracies are computed in-program (post-local-phase params, same
    point in the protocol as :func:`swarm_round`) because the brain
    storm ranks them.
    ``with_loss=True`` (exclusive with ``with_eval``) keeps the
    eval-free signature but returns the last-step loss alongside the
    stats — ``round_step(sparams, sopt, batch, lr, clusters, weights)
    -> (sparams, sopt, stats, loss)``. This is the bucketed-eval driver
    surface: a rectangular in-program val stack would reintroduce
    pad-to-global-max, so the driver evaluates per size bucket with its
    own fixed-shape compiled programs (one per bucket signature) and
    the round program carries only the O(1) loss out.

    ``axis_name`` switches the body onto the shard_map layout: every
    client-stacked argument is the *local* slice of a client axis split
    over that mesh axis, and Eq. 2 runs as the psum formulation
    (:func:`~repro.core.aggregation.cluster_fedavg_psum`) — the layout
    ``swarm_fleet.fleet_setup(spmd="shard_map")`` wraps, which is how
    the driver runs vmapped-conv clients the XLA partitioner cannot
    auto-shard over ``pod``. ``axis_name=None`` keeps the plain stacked
    layout for GSPMD auto-partitioning (the LM dry-run path).

    ``with_churn`` appends two (N,) bool operands to whichever surface
    was selected — ``round_step(..., present, agg_present)``: the
    fault-injection regime of the fleet driver. ``agg_present`` gates
    the incoming Eq. 2 (who *receives* the previous round's decision —
    the masked aggregation variants, with the driver's host-computed
    staleness weights riding the existing ``weights`` operand) and
    ``present`` masks this round's local phase (dropped pods run
    masked no-op steps). All-ones masks reproduce the churn-free body
    bitwise, so the driver uses one program for both regimes.

    ``hier_k_local > 0`` selects the HIERARCHICAL surface instead (it
    implies the in-program eval and is exclusive with
    ``with_eval``/``with_loss``): the stat upload never leaves the
    mesh — each pod runs a local ``k_local``-means over its members'
    stats in-program and only the O(pods * k_local)
    :class:`HierRoundOut` summaries cross to the host, which answers
    with a (S,) pod-cluster -> global-cluster map ``g`` instead of a
    (N,) client decision. The signature becomes::

        round_step(sparams, sopt, batch, val, lr, g, use_composed,
                   clusters0, a_prev, kmkey, weights[, present,
                   agg_present, report]) -> (sparams, sopt,
                                             HierRoundOut)

    The incoming Eq. 2 decision is composed IN-PROGRAM:
    ``where(use_composed, g[a_prev], clusters0)`` — ``a_prev`` is the
    previous round's device-resident ``a_local`` feedback, ``clusters0``
    a device-resident fallback (the driver feeds singletons, making
    round 0's aggregation the bitwise identity exactly like the flat
    driver), and ``use_composed`` a traced () bool that flips after
    round 0 — so neither the O(N) fallback nor the assignments ever
    cross the host boundary per round. ``kmkey`` seeds pod ``p``'s
    k-means via ``fold_in(kmkey, p)`` (the pod index is
    ``axis_index(axis_name)`` under shard_map, the static loop index on
    the GSPMD path, where ``hier_pods`` must divide the client count
    into equal contiguous pods). With ``with_churn`` a THIRD mask
    ``report`` joins ``(present, agg_present)``: it masks the pod
    k-means and the summary sums — a straggler trains but misses the
    summary deadline, so the hier coordinator sees only fresh reports
    (there is no per-client last-seen cache host-side; that cache is
    O(clients), the very thing this surface removes).
    """
    step = make_train_step(model, opt)

    def body(sparams, sopt, batch, lr, clusters, weights,
             present=None, agg_present=None):
        # Eq. 2 on the incoming (previous-round) coordinator decision
        if agg_present is not None:
            if axis_name is None:
                sparams = cluster_fedavg_masked(sparams, clusters, weights,
                                                agg_present, k=k)
            else:
                sparams = cluster_fedavg_psum_masked(
                    sparams, clusters, weights, agg_present, k=k,
                    axis_name=axis_name)
        elif axis_name is None:
            sparams = cluster_fedavg(sparams, clusters, weights, k=k)
        else:
            sparams = cluster_fedavg_psum(sparams, clusters, weights, k=k,
                                          axis_name=axis_name)
        # ceil-sized microbatches with a clamped final start cover every
        # row (indivisible batches overlap slightly at the tail instead
        # of silently dropping rows); training n_local_steps times on
        # the identical batch would not be SGD.
        n_b = jax.tree.leaves(batch)[0].shape[1]
        mb = min(n_b, -(-n_b // n_local_steps))

        def batch_for_step(i):
            start = jnp.minimum(i * mb, n_b - mb)
            return jax.tree.map(
                lambda x: jax.lax.dynamic_slice_in_dim(x, start, mb, 1),
                batch)

        sparams, sopt, losses = local_phase(step, sparams, sopt, lr,
                                            jnp.arange(n_local_steps),
                                            batch_for_step, present=present)
        stats = swarm_distribution_matrix(sparams, use_pallas=use_pallas)
        return sparams, sopt, stats, losses

    if hier_k_local > 0:
        if with_eval or with_loss:
            raise ValueError("hier_k_local selects its own eval surface "
                             "— drop with_eval/with_loss")
        kl = int(hier_k_local)
        client_eval = make_client_eval(model)

        def _pod_summary(stats, val_acc, weights, report, key, pod_idx):
            C, a = kmeans(key, stats, k=kl, iters=hier_kmeans_iters,
                          mask=report)
            w = (jnp.ones(stats.shape[:1], stats.dtype) if report is None
                 else jnp.asarray(report, stats.dtype))
            counts = jax.ops.segment_sum(w, a, kl)
            wsums = jax.ops.segment_sum(weights * w, a, kl)
            valsums = jax.ops.segment_sum(val_acc * w, a, kl)
            pc = pod_idx * kl + a.astype(jnp.int32)
            return C, counts, wsums, valsums, pc

        def round_step_hier(sparams, sopt, batch, val, lr, g, use_comp,
                            clusters0, a_prev, kmkey, weights,
                            *churn_masks):
            kw = {}
            report = None
            if with_churn:
                present, agg_present, report = churn_masks
                kw = {"present": present, "agg_present": agg_present}
            # the incoming decision, composed on-mesh: round 0 rides the
            # device-resident fallback (the driver feeds singletons — the
            # bitwise-identity Eq. 2, exactly the flat driver's round 0)
            clusters = jnp.where(use_comp, g[a_prev], clusters0)
            sparams, sopt, stats, losses = body(
                sparams, sopt, batch, lr, clusters, weights, **kw)
            val_acc = client_eval(sparams, val)
            loss = losses[-1]
            mean_val = jnp.mean(val_acc)
            if axis_name is not None:
                loss = jax.lax.pmean(loss, axis_name)
                mean_val = jax.lax.pmean(mean_val, axis_name)
                pod = jax.lax.axis_index(axis_name)
                C, counts, wsums, valsums, pc = _pod_summary(
                    stats, val_acc, weights, report,
                    jax.random.fold_in(kmkey, pod), pod)
            else:
                n_loc = stats.shape[0]
                P = int(hier_pods)
                if P <= 0 or n_loc % P:
                    raise ValueError(
                        "the GSPMD hier surface needs hier_pods to "
                        f"divide the client count into equal contiguous "
                        f"pods (hier_pods={P}, clients={n_loc})")
                m = n_loc // P
                outs = []
                for p in range(P):
                    sl = slice(p * m, (p + 1) * m)
                    outs.append(_pod_summary(
                        stats[sl], val_acc[sl], weights[sl],
                        None if report is None else report[sl],
                        jax.random.fold_in(kmkey, p), p))
                C = jnp.concatenate([o[0] for o in outs], axis=0)
                counts = jnp.concatenate([o[1] for o in outs])
                wsums = jnp.concatenate([o[2] for o in outs])
                valsums = jnp.concatenate([o[3] for o in outs])
                pc = jnp.concatenate([o[4] for o in outs])
            return sparams, sopt, HierRoundOut(
                centroids=C, counts=counts, wsums=wsums, valsums=valsums,
                a_local=pc, mean_val=mean_val, train_loss=loss)

        return round_step_hier

    if with_eval:
        client_eval = make_client_eval(model)

        def round_step_eval(sparams, sopt, batch, val, lr, clusters,
                            weights, *churn_masks):
            kw = {}
            if with_churn:
                present, agg_present = churn_masks
                kw = {"present": present, "agg_present": agg_present}
            sparams, sopt, stats, losses = body(sparams, sopt, batch, lr,
                                                clusters, weights, **kw)
            val_acc = client_eval(sparams, val)
            loss = losses[-1]
            if axis_name is not None:
                # per-shard means -> the global mean (equal local counts)
                loss = jax.lax.pmean(loss, axis_name)
            return sparams, sopt, FleetRoundOut(stats=stats,
                                                val_acc=val_acc,
                                                train_loss=loss)

        return round_step_eval

    if with_loss:

        def round_step_loss(sparams, sopt, batch, lr, clusters, weights,
                            *churn_masks):
            kw = {}
            if with_churn:
                present, agg_present = churn_masks
                kw = {"present": present, "agg_present": agg_present}
            sparams, sopt, stats, losses = body(sparams, sopt, batch, lr,
                                                clusters, weights, **kw)
            loss = losses[-1]
            if axis_name is not None:
                loss = jax.lax.pmean(loss, axis_name)
            return sparams, sopt, stats, loss

        return round_step_loss

    def round_step(sparams, sopt, batch, lr, clusters, weights,
                   *churn_masks):
        kw = {}
        if with_churn:
            present, agg_present = churn_masks
            kw = {"present": present, "agg_present": agg_present}
        sparams, sopt, stats, _ = body(sparams, sopt, batch, lr, clusters,
                                       weights, **kw)
        return sparams, sopt, stats

    return round_step
