"""Functional BSO-SL round engine: ONE jit'd program per round.

The paper's round (§III) — local SGD → distribution upload → k-means →
brain-storm aggregation — is expressed here as a pure function over an
explicit :class:`SwarmState` pytree::

    state, metrics = swarm_round(state, data, cfg)

Everything inside is traceable: local-training batches are sampled
on-device (`jax.random` gather over the device-resident stacked
dataset in :class:`SwarmData`), the coordinator runs the jax
``brain_storm_jax`` port, and Eq. 2 aggregation is the segment-sum
``cluster_fedavg``. A whole sim-regime round is therefore a single
device program, and :func:`run_rounds` scans it over rounds so a full
``fit`` is ONE program too.

Both regimes share this body:

* **sim** — :func:`swarm_round`; the stateful
  :class:`repro.core.swarm.SwarmTrainer` is a thin host wrapper.
* **fleet** — :func:`make_fleet_round` composes the same
  :func:`local_phase` + in-program distribution-stat upload
  (``param_stats_batched`` under ``use_pallas``) + ``cluster_fedavg``;
  only the O(clients) coordinator decision (k-means + brain storm)
  arrives from the host, matching the paper's neighbour-assignment
  server (see ``repro/launch/swarm_fleet.py``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.aggregation import cluster_fedavg
from repro.core.bso import brain_storm_jax
from repro.core.diststats import swarm_distribution_matrix
from repro.core.kmeans import kmeans
from repro.models.model import Model
from repro.optim.optimizers import Optimizer
from repro.train.steps import make_eval_step, make_train_step

# --------------------------------------------------------------------- state


class SwarmState(NamedTuple):
    """The complete mutable state of a swarm, as one pytree.

    Every field has a leading client axis N where applicable, so the
    state threads through jit/scan/donation without host round-trips.
    """
    params: Any                      # client-stacked model pytree (N, ...)
    opt_state: Any                   # client-stacked optimizer pytree
    key: Any                         # PRNG key driving sampling + BSA
    round: Any                       # () int32 round counter
    n_samples: Any                   # (N,) float32 |D_h| (Eq. 2 weights)


class SwarmData(NamedTuple):
    """Device-resident, fixed-shape swarm dataset.

    train:   batch pytree with shape (N, n_max, ...); clients shorter
             than n_max are padded (pad rows are never sampled).
    train_n: (N,) int32 true train-set sizes — the sampling bound.
    val:     client-stacked eval batches (N, n_batches, batch, ...)
             with label=-1 masking (see :func:`stack_eval_split`).
    """
    train: Any
    train_n: Any
    val: Any


class RoundMetrics(NamedTuple):
    """Per-round outputs (all device scalars/arrays, scan-stackable)."""
    mean_val_acc: Any                # () — paper Eq. 3 on the val split
    val_acc: Any                     # (N,) per-client val accuracy
    train_loss: Any                  # () mean loss of the last local step
    assignments: Any                 # (N,) int32 post-BSA clusters
    centers: Any                     # (k,) int32 center client ids
    n_replaced: Any                  # () int32 BSA replacement events
    n_swapped: Any                   # () int32 BSA swap events


@dataclass(frozen=True)
class EngineConfig:
    """Static round configuration (hashable — a jit static argument).

    Holds the model/optimizer *objects*: both are frozen dataclasses of
    pure functions, so configs built from the same instances hash equal
    and share the compiled round program.
    """
    model: Model
    opt: Optimizer
    local_steps: int
    batch_size: int
    lr: float
    aggregation: str = "bso"         # bso | fedavg | none
    n_clusters: int = 3
    p1: float = 0.9
    p2: float = 0.8
    kmeans_iters: int = 20
    use_pallas: bool = False
    reset_opt_each_round: bool = False
    local_unroll: int = 1            # scan unroll of the local phase
                                     # (CPU wants local_steps, TPU 1)


# --------------------------------------------------------------- data layout


def make_batch(cfg: ModelConfig, X, y):
    if cfg.family == "cnn":
        return {"images": jnp.asarray(X), "labels": jnp.asarray(y)}
    return {"tokens": jnp.asarray(X), "labels": jnp.asarray(y)}


def pad_eval_split(X, y, n_to: int):
    """Pad an eval slice to ``n_to`` rows: zero inputs, label=-1 rows
    (the loss/accuracy mask) — the one copy of the masking convention
    shared by the per-client loop and the stacked vmapped eval."""
    pad = n_to - len(y)
    if pad:
        X = np.concatenate([X, np.zeros((pad,) + X.shape[1:], X.dtype)])
        y = np.concatenate([y, -np.ones((pad,) + y.shape[1:], y.dtype)])
    return X, y


def stack_eval_split(cfg: ModelConfig, clients_data, split: str,
                     batch: int = 64):
    """Client-stacked eval data for one split, shaped
    (N, n_batches, batch, ...): every client padded to the largest
    client rounded up to the microbatch size, pad rows label=-1
    (masked)."""
    n_max = max(len(c[split][1]) for c in clients_data)
    n_to = -(-n_max // batch) * batch
    Xs, ys = [], []
    for c in clients_data:
        X, y = pad_eval_split(*c[split], n_to)
        Xs.append(X.reshape((n_to // batch, batch) + X.shape[1:]))
        ys.append(y.reshape((n_to // batch, batch) + y.shape[1:]))
    return make_batch(cfg, np.stack(Xs), np.stack(ys))


def make_swarm_data(cfg: ModelConfig, clients_data, *,
                    eval_batch: int = 64) -> SwarmData:
    """Build the device-resident :class:`SwarmData` from the per-clinic
    host dicts. Train sets are padded to the largest client with
    label=-1 poison rows; ``train_n`` bounds the on-device sampler so
    pads are never drawn."""
    n_max = max(len(c["train"][1]) for c in clients_data)
    Xs, ys = [], []
    for c in clients_data:
        X, y = pad_eval_split(*c["train"], n_max)
        Xs.append(X)
        ys.append(y)
    train = make_batch(cfg, np.stack(Xs), np.stack(ys))
    train_n = jnp.asarray([len(c["train"][1]) for c in clients_data],
                          jnp.int32)
    return SwarmData(train=train, train_n=train_n,
                     val=stack_eval_split(cfg, clients_data, "val",
                                          batch=eval_batch))


def make_swarm_state(model: Model, opt: Optimizer, clients_data,
                     key) -> SwarmState:
    """Fresh per-client params/opt state + the round-driving key."""
    init_key, round_key = jax.random.split(key)
    keys = jax.random.split(init_key, len(clients_data))
    params = jax.vmap(model.init)(keys)
    opt_state = jax.vmap(opt.init)(params)
    n_samples = jnp.asarray([c["n_train"] for c in clients_data],
                            jnp.float32)
    return SwarmState(params=params, opt_state=opt_state, key=round_key,
                      round=jnp.zeros((), jnp.int32), n_samples=n_samples)


# -------------------------------------------------------------- round pieces


def sample_local_batch(key, train, train_n, batch_size: int):
    """On-device per-client minibatch: uniform-with-replacement indices
    bounded per client by ``train_n`` (pad rows are unreachable), then a
    vmapped gather — no host loop, no data transfer."""
    N = train_n.shape[0]
    idx = jax.random.randint(key, (N, batch_size), 0, train_n[:, None])
    return jax.tree.map(
        lambda x: jax.vmap(lambda a, i: a[i])(x, idx), train)


def local_phase(step, params, opt_state, lr, xs, batch_for_step, *,
                unroll: int = 1):
    """The shared local-training body of both regimes: a scan of
    vmapped train steps over the client axis.

    ``xs`` is the scan input (sim: per-step sample keys; fleet: step
    indices) and ``batch_for_step(x)`` materialises that step's stacked
    (N, B, ...) batch — sampling a fresh gather in the sim regime,
    slicing the uploaded round batch in the fleet regime.

    ``unroll`` trades compile time for loop overhead: XLA's CPU backend
    executes ops inside a while body markedly slower than the same ops
    unrolled (~2x on convs), so CPU benchmarking wants
    ``unroll=len(xs)``; TPU and large models want the rolled default."""
    vstep = jax.vmap(step, in_axes=(0, 0, 0, None))

    def body(carry, x):
        p, o = carry
        p, o, m = vstep(p, o, batch_for_step(x), lr)
        return (p, o), jnp.mean(m["loss"])

    (params, opt_state), losses = jax.lax.scan(body, (params, opt_state),
                                               xs, unroll=unroll)
    return params, opt_state, losses


def make_client_eval(model: Model):
    """Per-client masked accuracy over stacked (N, n_batches, batch, ..)
    eval data — one vmapped program, scanning fixed microbatches so the
    activation footprint stays O(N * batch) regardless of split size."""
    eval_step = make_eval_step(model)

    def client_eval(params, batches):
        def one(carry, bt):
            hits, tot = carry
            m = eval_step(params, bt)
            valid = jnp.sum(bt["labels"] >= 0).astype(jnp.float32)
            return (hits + m["acc"] * valid, tot + valid), None

        (hits, tot), _ = jax.lax.scan(
            one, (jnp.float32(0.0), jnp.float32(0.0)), batches)
        return hits / jnp.maximum(tot, 1.0)

    return jax.vmap(client_eval)


# ---------------------------------------------------------------- the round


def swarm_round(state: SwarmState, data: SwarmData,
                cfg: EngineConfig):
    """One full BSO-SL round as a pure function — local steps, eval,
    distribution upload, k-means, brain storm, Eq. 2 aggregation.

    Jit it with ``cfg`` static (see :data:`jit_swarm_round`) and the
    entire round is one device program; scan it (:func:`run_rounds`)
    and a whole training run is one program."""
    model, opt = cfg.model, cfg.opt
    step = make_train_step(model, opt)
    next_key, k_local, k_kmeans, k_bso = jax.random.split(state.key, 4)

    # --- local phase: cfg.local_steps of on-device-sampled SGD
    sample_keys = jax.random.split(k_local, cfg.local_steps)
    params, opt_state, losses = local_phase(
        step, state.params, state.opt_state, cfg.lr, sample_keys,
        lambda kt: sample_local_batch(kt, data.train, data.train_n,
                                      cfg.batch_size),
        unroll=cfg.local_unroll)
    train_loss = losses[-1]

    # --- eval: per-client val accuracy (shared within clusters, §III.C)
    val = make_client_eval(model)(params, data.val)

    # --- coordinator + aggregation
    N = data.train_n.shape[0]
    zero = jnp.zeros((), jnp.int32)
    if cfg.aggregation == "none":
        assignments = jnp.zeros((N,), jnp.int32)
        centers = jnp.zeros((0,), jnp.int32)
        n_rep = n_swap = zero
    else:
        if cfg.aggregation == "fedavg":
            k = 1
            assignments = jnp.zeros((N,), jnp.int32)
            centers = jnp.argmax(val)[None].astype(jnp.int32)
            n_rep = n_swap = zero
        else:
            k = cfg.n_clusters
            feats = swarm_distribution_matrix(params,
                                              use_pallas=cfg.use_pallas)
            _, a0 = kmeans(k_kmeans, feats, k=k, iters=cfg.kmeans_iters,
                           use_pallas=cfg.use_pallas)
            assignments, centers, n_rep, n_swap = brain_storm_jax(
                k_bso, a0, val, k, cfg.p1, cfg.p2)
        params = cluster_fedavg(params, assignments, state.n_samples, k=k)
        if cfg.reset_opt_each_round:
            opt_state = jax.vmap(opt.init)(params)

    new_state = SwarmState(params=params, opt_state=opt_state, key=next_key,
                           round=state.round + 1, n_samples=state.n_samples)
    metrics = RoundMetrics(mean_val_acc=jnp.mean(val), val_acc=val,
                           train_loss=train_loss, assignments=assignments,
                           centers=centers, n_replaced=n_rep,
                           n_swapped=n_swap)
    return new_state, metrics


def run_rounds(state: SwarmState, data: SwarmData, cfg: EngineConfig,
               rounds: int):
    """Scan :func:`swarm_round` over ``rounds``: the whole multi-round
    fit as ONE device program. Metrics gain a leading (rounds,) axis."""
    def body(s, _):
        return swarm_round(s, data, cfg)

    return jax.lax.scan(body, state, None, length=rounds)


# module-level jitted entry points: the cache is shared across every
# host wrapper holding an equal EngineConfig (state buffers donated —
# each round updates the swarm in place)
jit_swarm_round = jax.jit(swarm_round, static_argnames=("cfg",),
                          donate_argnums=(0,))
jit_run_rounds = jax.jit(run_rounds, static_argnames=("cfg", "rounds"),
                         donate_argnums=(0,))


# ------------------------------------------------------------- fleet regime


def make_fleet_round(model: Model, opt: Optimizer, k: int,
                     n_local_steps: int = 1, *, use_pallas: bool = False):
    """Fleet round built from the same body as :func:`swarm_round`:
    the shared :func:`local_phase` (per-step microbatch slices of the
    uploaded round batch instead of on-device sampling), then the
    distribution-stat upload computed *inside* the program — the
    ``param_stats_batched`` kernel under ``use_pallas``, the jnp oracle
    otherwise — so the O(#tensors) stats ride the same collective as
    the round step, then Eq. 2 ``cluster_fedavg`` (XLA SPMD inserts the
    cross-pod collectives).

    Only the O(clients) coordinator decision (k-means + brain storm)
    stays host-side, matching the paper's neighbour-assignment server:
    ``clusters`` is next round's post-BSA assignment computed from the
    ``stats`` this round returns.

    Returns ``round_step(sparams, sopt, batch, lr, clusters, weights)
    -> (sparams, sopt, stats)``.
    """
    step = make_train_step(model, opt)

    def round_step(sparams, sopt, batch, lr, clusters, weights):
        # ceil-sized microbatches with a clamped final start cover every
        # row (indivisible batches overlap slightly at the tail instead
        # of silently dropping rows); training n_local_steps times on
        # the identical batch would not be SGD.
        n_b = jax.tree.leaves(batch)[0].shape[1]
        mb = min(n_b, -(-n_b // n_local_steps))

        def batch_for_step(i):
            start = jnp.minimum(i * mb, n_b - mb)
            return jax.tree.map(
                lambda x: jax.lax.dynamic_slice_in_dim(x, start, mb, 1),
                batch)

        sparams, sopt, _ = local_phase(step, sparams, sopt, lr,
                                       jnp.arange(n_local_steps),
                                       batch_for_step)
        stats = swarm_distribution_matrix(sparams, use_pallas=use_pallas)
        sparams = cluster_fedavg(sparams, clusters, weights, k=k)
        return sparams, sopt, stats

    return round_step
