"""The paper's primary contribution: the BSO-SL protocol.

local training -> distribution upload -> k-means clustering ->
brain-storm aggregation (center select / replace / swap + Eq.2 FedAvg).
"""
from repro.core.aggregation import cluster_fedavg, cluster_psum_fedavg, fedavg  # noqa: F401
from repro.core.bso import BSAPlan, brain_storm  # noqa: F401
from repro.core.diststats import param_distribution, swarm_distribution_matrix  # noqa: F401
from repro.core.kmeans import kmeans  # noqa: F401
from repro.core.swarm import SwarmTrainer  # noqa: F401
