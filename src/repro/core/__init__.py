"""The paper's primary contribution: the BSO-SL protocol.

local training -> distribution upload -> k-means clustering ->
brain-storm aggregation (center select / replace / swap + Eq.2 FedAvg).

The round itself is the pure functional engine in
:mod:`repro.core.engine` (``swarm_round`` over a ``SwarmState``
pytree); :class:`repro.core.swarm.SwarmTrainer` is the stateful host
wrapper.
"""
from repro.core.aggregation import cluster_fedavg, cluster_psum_fedavg, fedavg  # noqa: F401
from repro.core.bso import BSAPlan, brain_storm, brain_storm_jax  # noqa: F401
from repro.core.diststats import param_distribution, swarm_distribution_matrix  # noqa: F401
from repro.core.engine import (EngineConfig, GridPoint,  # noqa: F401
                               MethodParams, RoundMetrics, SwarmData,
                               SwarmState, grid_axes, grid_point,
                               jit_run_grid, jit_run_rounds, jit_run_sweep,
                               jit_swarm_round, make_fleet_round,
                               make_grid_config, make_grid_state,
                               make_swarm_data, make_swarm_state,
                               make_sweep_config, make_sweep_state,
                               run_grid, run_rounds, run_sweep, swarm_round)
from repro.core.kmeans import kmeans  # noqa: F401
from repro.core.swarm import SwarmTrainer  # noqa: F401
