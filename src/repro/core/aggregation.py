"""Parameter aggregation (paper Eq. 2) — the client-to-client step.

Sim regime: clients live on one host as a stacked pytree; cluster
FedAvg is a segment-sum over the client axis (jit-able, O(N) with no
server bottleneck).

Fleet regime: the identical math expressed as a *masked weighted psum*
over the ``clients`` mesh axis inside shard_map — cluster-restricted
all-reduce, i.e. swarm learning's peer-to-peer exchange as a TPU
collective (see repro/launch/swarm_fleet.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_weighted_sum


def fedavg(params_list, n_samples):
    """Classic FedAvg over an explicit list of client pytrees."""
    w = jnp.asarray(n_samples, jnp.float32)
    w = w / jnp.maximum(w.sum(), 1e-9)
    return tree_weighted_sum(params_list, w)


def singleton_assignments(n: int):
    """Assignments placing every client in its own cluster, which makes
    :func:`cluster_fedavg` (with ``k >= n``) the *bitwise* identity:
    each singleton's weight normalises to exactly ``w / w == 1.0`` and
    its segment sum is a single float32 copy. This is how the sweep
    engine expresses the paper's local-only baseline as the same
    aggregation program as the other methods."""
    return jnp.arange(n, dtype=jnp.int32)


def cluster_fedavg(stacked_params, assignments, n_samples, k: int):
    """Eq. 2 within every cluster simultaneously.

    stacked_params: pytree with leading client axis N.
    assignments:    (N,) int cluster ids (post brain-storm).
    n_samples:      (N,) training set sizes |D_h|.
    ``k`` only needs to upper-bound the labels in ``assignments``;
    passing ``k = N`` with labels drawn from a smaller range computes
    the same sums *bitwise* — per-segment partial sums and the gather
    back are unchanged by trailing empty segments. The sweep AND grid
    engines rely on exactly this: one ``k = N`` segment layout serves
    every Table-II method row and every masked-k grid row (whose
    k-means labels live in ``[0, point.n_clusters)`` under the static
    pad ``k_max``), so the aggregation plan never needs a traced
    segment count.
    Returns the stacked pytree where client i holds its cluster's
    aggregated model (the redistribution step).
    """
    assignments = jnp.asarray(assignments)
    w = jnp.asarray(n_samples, jnp.float32)
    # per-cluster weight normalisation: |D_h| / |D_{G_k}|
    cluster_tot = jax.ops.segment_sum(w, assignments, num_segments=k)
    wn = w / jnp.maximum(cluster_tot[assignments], 1e-9)

    def agg_leaf(leaf):
        lf = leaf.astype(jnp.float32)
        weighted = lf * wn.reshape((-1,) + (1,) * (lf.ndim - 1))
        sums = jax.ops.segment_sum(weighted, assignments, num_segments=k)
        return sums[assignments].astype(leaf.dtype)

    return jax.tree.map(agg_leaf, stacked_params)


def cluster_fedavg_masked(stacked_params, assignments, weights, present,
                          k: int):
    """Churn-aware Eq. 2: participation-masked cluster FedAvg.

    The same op sequence as :func:`cluster_fedavg` — per-cluster weight
    normalisation, weighted segment-sum, gather back — with two churn
    semantics on top:

    * ``weights`` are the *effective* Eq. 2 weights, not raw |D_h|:
      the caller has already folded participation in (0 for a
      hard-masked absent client, |D_h|·λ^staleness for the
      staleness-weighted option), so an absent client contributes
      nothing (or a decayed echo) to its cluster's aggregate.
    * ``present`` gates who RECEIVES: absent clients keep their own
      (stale) params instead of taking the cluster aggregate — they
      were not part of this round's exchange.

    A cluster whose total effective weight is zero (every member absent
    under hard masking) produces no aggregate; any client reading from
    it falls back to its own params — the explicit guard that keeps the
    zero denominator from ever surfacing as NaNs. (K-means handles the
    same situation upstream via its empty-cluster reseed when the stats
    matrix is masked; this guard covers assignments arriving from
    *outside* k-means, e.g. a stale coordinator decision.)

    With ``present`` all-ones and ``weights = n_samples * 1.0`` this is
    BITWISE :func:`cluster_fedavg`: multiplying a float by 1.0 is
    exact, ``where(True, agg, own)`` is the identity, and positive
    |D_h| keep every cluster total strictly positive —
    ``tests/test_churn.py`` pins the equivalence.
    """
    assignments = jnp.asarray(assignments)
    w = jnp.asarray(weights, jnp.float32)
    present = jnp.asarray(present, bool)
    cluster_tot = jax.ops.segment_sum(w, assignments, num_segments=k)
    wn = w / jnp.maximum(cluster_tot[assignments], 1e-9)
    # receive = participated AND the cluster actually aggregated
    take = present & (cluster_tot[assignments] > 0.0)

    def agg_leaf(leaf):
        lf = leaf.astype(jnp.float32)
        weighted = lf * wn.reshape((-1,) + (1,) * (lf.ndim - 1))
        sums = jax.ops.segment_sum(weighted, assignments, num_segments=k)
        agg = sums[assignments].astype(leaf.dtype)
        m = take.reshape((-1,) + (1,) * (leaf.ndim - 1))
        return jnp.where(m, agg, leaf)

    return jax.tree.map(agg_leaf, stacked_params)


def cluster_fedavg_psum(stacked_params, assignments, n_samples, k: int,
                        axis_name: str):
    """Eq. 2 for a *local slice* of the client axis inside shard_map —
    the fleet driver's aggregation.

    Same math as :func:`cluster_fedavg`, with the client axis split
    over the ``axis_name`` mesh axis (the fleet's ``pod`` axis): each
    shard segment-sums its local clients into the global ``k`` cluster
    slots, one psum per pytree (the swarm's client-to-client exchange
    as a collective), then every client reads back its cluster's sum.
    ``assignments`` / ``n_samples`` are the local (n_local,) slices
    carrying *global* cluster ids. With one client per pod this is
    :func:`cluster_psum_fedavg`'s math on a batched layout; with the
    whole swarm in one shard it reduces to :func:`cluster_fedavg`.
    """
    assignments = jnp.asarray(assignments)
    w = jnp.asarray(n_samples, jnp.float32)
    cluster_tot = jax.lax.psum(
        jax.ops.segment_sum(w, assignments, num_segments=k), axis_name)
    wn = w / jnp.maximum(cluster_tot[assignments], 1e-9)

    def agg_leaf(leaf):
        lf = leaf.astype(jnp.float32)
        weighted = lf * wn.reshape((-1,) + (1,) * (lf.ndim - 1))
        sums = jax.lax.psum(
            jax.ops.segment_sum(weighted, assignments, num_segments=k),
            axis_name)
        return sums[assignments].astype(leaf.dtype)

    return jax.tree.map(agg_leaf, stacked_params)


def cluster_fedavg_psum_masked(stacked_params, assignments, weights,
                               present, k: int, axis_name: str):
    """:func:`cluster_fedavg_masked` for a *local slice* of the client
    axis inside shard_map — the fleet driver's churn-regime aggregation.
    ``assignments`` / ``weights`` / ``present`` are local slices with
    global cluster ids; the segment sums ride one psum each, and the
    zero-weight-cluster guard plus the present-only receive mask apply
    shard-locally (every shard sees the same psum'd cluster totals)."""
    assignments = jnp.asarray(assignments)
    w = jnp.asarray(weights, jnp.float32)
    present = jnp.asarray(present, bool)
    cluster_tot = jax.lax.psum(
        jax.ops.segment_sum(w, assignments, num_segments=k), axis_name)
    wn = w / jnp.maximum(cluster_tot[assignments], 1e-9)
    take = present & (cluster_tot[assignments] > 0.0)

    def agg_leaf(leaf):
        lf = leaf.astype(jnp.float32)
        weighted = lf * wn.reshape((-1,) + (1,) * (lf.ndim - 1))
        sums = jax.lax.psum(
            jax.ops.segment_sum(weighted, assignments, num_segments=k),
            axis_name)
        agg = sums[assignments].astype(leaf.dtype)
        m = take.reshape((-1,) + (1,) * (leaf.ndim - 1))
        return jnp.where(m, agg, leaf)

    return jax.tree.map(agg_leaf, stacked_params)


def cluster_psum_fedavg(params, weight, my_cluster, k: int, axis_name: str):
    """Fleet-regime Eq. 2: inside shard_map over the client axis.

    params: this client's pytree; weight: scalar |D_h|;
    my_cluster: () int32 — this client's (post brain-storm) cluster id.

    One masked psum per cluster (k is small — 3 in the paper): every
    client contributes its weighted params only to its own cluster's
    sum, then reads back the sum for its cluster. Pure client-to-client
    collectives — no server, and a psum is exactly the "exchange
    parameters with peers" traffic of swarm learning on ICI/DCN.
    """
    my_w = weight.astype(jnp.float32)

    def one_cluster(c):
        sel = (my_cluster == c).astype(jnp.float32)
        num = jax.tree.map(
            lambda x: jax.lax.psum(x.astype(jnp.float32) * (my_w * sel), axis_name),
            params)
        den = jax.lax.psum(my_w * sel, axis_name)
        return num, den

    nums, dens = [], []
    for c in range(k):
        n, d = one_cluster(c)
        nums.append(n)
        dens.append(d)

    dens = jnp.stack(dens)                                # (k,)
    my_den = jnp.maximum(dens[my_cluster], 1e-9)

    def pick(x, *cluster_leaves):
        stacked = jnp.stack(cluster_leaves)               # (k, ...)
        return (stacked[my_cluster] / my_den).astype(x.dtype)

    return jax.tree.map(pick, params, *nums)
