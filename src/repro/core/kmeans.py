"""k-means over client distribution summaries (paper §III.B).

Lloyd iterations with k-means++ seeding, fully jit-able
(lax.fori_loop + static k). Empty clusters are re-seeded to *distinct*
far points — the j-th empty cluster takes the j-th farthest point from
its assigned centroid — so k clusters survive even with N=14 clients
and re-seeded centroids can actually separate (a single shared far
point would leave duplicate centroids forever).

**Masked static-max clusters** (the grid engine's k axis): every entry
point takes an optional traced ``k_active`` — the static ``k`` becomes
an upper bound (pad), and only clusters ``< k_active`` can be seeded,
assigned to, or re-seeded. Per-index randomness derives from
``fold_in(key, i)`` rather than a shape-``(k,)`` draw, so the first
``k_active`` draws are *bitwise identical* no matter the static pad:
a ``k=k_max, k_active=j`` run reproduces a native ``k=j`` run exactly
(``tests/test_grid.py`` pins this), which is what lets
``engine.run_grid`` vmap a cluster-count ablation into one program.
``k_active=None`` keeps the plain static-k path.

**Masked points** (the churn engine's participation axis): every entry
point also takes an optional traced ``mask`` over the N points — absent
clients keep receiving assignments (cluster membership feeds the
staleness-weighted Eq. 2) but contribute nothing to seeding or centroid
means, and a cluster whose members are all absent is treated as empty
and rides the far-point reseed (present candidates only). An all-ones
mask is bitwise the unmasked run.

**Weighted points** (the hierarchical engine's summary axis): every
entry point also takes an optional traced ``weights`` over the N points
— the input rows may themselves be *centroids from a lower tier*
carrying member counts, so seeding probabilities scale to ``d * w``,
centroid means become weight-weighted means, and zero-weight rows are
excluded from seeding and reseeds exactly like masked-out points (a
pod-cluster that captured no clients must not anchor a global
centroid). ``weights=None`` is bitwise the unweighted run; ``weights``
composes multiplicatively with ``mask``.

The distance/assign step has two interchangeable implementations:
the jnp path below (the oracle) and the ``kmeans_assign`` Pallas kernel
(``use_pallas=True``) — one distance-matmul+argmin device program per
Lloyd iteration. The masked path always assigns through the jnp
implementation (the kernel has no mask operand); since ``k`` is tiny
the matmul is negligible either way.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _pairwise_sq_dists(X, C):
    """(N, K) squared euclidean distances."""
    x2 = jnp.sum(X * X, axis=1, keepdims=True)
    c2 = jnp.sum(C * C, axis=1)[None, :]
    return jnp.maximum(x2 + c2 - 2.0 * X @ C.T, 0.0)


def _point_weights(X, mask, weights):
    """Combine the participation mask and per-point weights into
    (wf, pos): a float scale for distances/means (or None when both
    inputs are None — the bitwise-unchanged fast path) and a bool
    eligibility mask for seeding/reseed targets (or None likewise).
    ``weights``-only and ``mask``-only paths each reproduce the
    respective single-axis behaviour; together they compose
    multiplicatively (an absent point keeps zero weight)."""
    if weights is None and mask is None:
        return None, None
    if weights is None:
        m = jnp.asarray(mask, bool)
        return m.astype(X.dtype), m
    w = jnp.asarray(weights, X.dtype)
    if mask is not None:
        w = w * jnp.asarray(mask, X.dtype)
    return w, w > 0


def kmeans_pp_init(key, X, k: int, mask=None, weights=None):
    """k-means++ seeding. Draws derive per-index from ``fold_in`` so
    seeds 0..j are identical for every static ``k >= j`` — the masked
    path's pad-invariance. Deliberately unmasked over *clusters*: pad
    slots beyond a caller's ``k_active`` still seed (fixed shapes,
    identical first ``k_active`` draws) and are masked out of every
    downstream assignment instead.

    ``mask`` (a traced (N,) participation mask, or None) excludes
    absent *points* from seeding: the first seed's uniform draw is
    remapped onto the present subsequence and the ++ probabilities of
    absent points are zeroed. With ``mask`` all-ones both moves are
    bitwise identities (the remap fixes the same index, ``d * 1.0`` is
    exact), so a fully-present masked run reproduces the unmasked run
    exactly — the churn engine's parity anchor.

    ``weights`` (a traced (N,) non-negative weight vector, or None)
    makes the seeding *weighted*: the first seed is uniform over
    positive-weight points and the ++ probabilities scale to
    ``d * w`` — the classic weighted-k-means++ rule, which is what lets
    the rows of ``X`` be lower-tier centroids carrying member counts.
    ``weights=None`` is bitwise the unweighted path."""
    N = X.shape[0]
    r0 = jax.random.randint(jax.random.fold_in(key, 0), (), 0, N)
    wf, pos = _point_weights(X, mask, weights)
    if pos is None:
        idx0 = r0
    else:
        # uniform over the eligible subsequence: r0 mod n_eligible
        # ranks into the cumulative-eligibility prefix (identity when
        # all eligible: cumsum hits r0+1 first at index r0)
        cum = jnp.cumsum(pos.astype(jnp.int32))
        rank = r0 % jnp.maximum(cum[-1], 1)
        idx0 = jnp.clip(jnp.searchsorted(cum, rank + 1), 0, N - 1)
    C = jnp.zeros((k, X.shape[1]), X.dtype).at[0].set(X[idx0])

    def body(i, C):
        # distances against the first i chosen centroids only
        valid = jnp.arange(k) < i
        dists = _pairwise_sq_dists(X, C)
        dists = jnp.where(valid[None, :], dists, jnp.inf)
        d = jnp.min(dists, axis=1)
        if wf is not None:
            d = d * wf
        p = d / jnp.maximum(d.sum(), 1e-12)
        nxt = jax.random.choice(jax.random.fold_in(key, i), N, p=p)
        return C.at[i].set(X[nxt])

    return jax.lax.fori_loop(1, k, body, C)


def assign(X, C, k_active=None):
    """Nearest-centroid assignment (the kmeans_assign kernel's math).
    With ``k_active`` only clusters ``< k_active`` are eligible."""
    d = _pairwise_sq_dists(X, C)
    if k_active is not None:
        d = jnp.where(jnp.arange(C.shape[0])[None, :] < k_active,
                      d, jnp.inf)
    return jnp.argmin(d, axis=1)


def _assign_fn(use_pallas: bool, k_active=None):
    if k_active is not None:
        # masked path: the Pallas kernel has no mask operand; the jnp
        # argmin over masked distances is the one implementation
        return lambda X, C: assign(X, C, k_active)
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.kmeans_assign
    return assign


def lloyd_step(X, C, k: int, *, use_pallas: bool = False, k_active=None,
               mask=None, weights=None):
    """One Lloyd iteration: assign, recompute means, reseed empties.
    Only clusters ``< k_active`` count as re-seedable empties — the
    inactive pad slots must stay out of the far-point budget or a
    ``k_active=j`` run would burn its farthest points on dead slots.

    ``mask`` (a traced (N,) participation mask, or None) is the churn
    engine's point axis: absent points are still *assigned* (their
    cluster membership feeds the staleness-weighted Eq. 2) but carry
    zero weight in the centroid means, and a cluster whose members are
    all absent counts as EMPTY — it rides the existing far-point reseed
    (restricted to present candidates), which is exactly the
    all-absent-cluster fallback the churn round relies on. All-ones
    mask is bitwise the unmasked step (``onehot * 1.0`` and
    ``where(True, d, -inf)`` are identities).

    ``weights`` (a traced (N,) non-negative weight vector, or None)
    turns the means into weighted means — ``counts`` become weight
    sums, so a row of ``X`` can stand for a whole pod-cluster of
    clients. Zero-weight rows behave like masked-out points (no vote
    in the means, never a reseed target, and a cluster holding only
    zero-weight rows counts as empty). ``weights=None`` keeps the
    unweighted denominator floor of 1.0 bitwise; with weights the
    floor drops to 1e-9 so fractional weight sums still produce true
    weighted means (empty rows get reseeded regardless)."""
    a = _assign_fn(use_pallas, k_active)(X, C)
    wf, pos = _point_weights(X, mask, weights)
    onehot = jax.nn.one_hot(a, k, dtype=X.dtype)             # (N, K)
    if wf is not None:
        onehot = onehot * wf[:, None]
    counts = onehot.sum(axis=0)                              # (K,)
    sums = onehot.T @ X                                      # (K, F)
    floor = 1.0 if weights is None else 1e-9
    newC = sums / jnp.maximum(counts[:, None], floor)
    # empty clusters -> distinct far points: rank points by distance to
    # their current centroid (farthest first) and hand the j-th empty
    # cluster the j-th farthest point. Distance to the *assigned*
    # centroid equals the min pairwise distance, so reuse `a` instead
    # of a second full (N, K) distance matmul (the Pallas assign call
    # is opaque to XLA's CSE).
    diff = X - C[a]
    d = jnp.sum(diff * diff, axis=1)
    if pos is not None:
        # absent / zero-weight points can never be reseed targets
        d = jnp.where(pos, d, -jnp.inf)
    far_order = jnp.argsort(-d)                              # (N,)
    empty = counts == 0
    if k_active is not None:
        empty = empty & (jnp.arange(k) < k_active)
    rank = jnp.clip(jnp.cumsum(empty.astype(jnp.int32)) - 1,
                    0, X.shape[0] - 1)                       # (K,)
    newC = jnp.where(empty[:, None], X[far_order[rank]], newC)
    return newC


def kmeans(key, X, k: int, iters: int = 20, *, use_pallas: bool = False,
           k_active=None, mask=None, weights=None):
    """Returns (centroids (k,F), assignments (N,)).

    ``k`` is static (shapes); ``k_active`` optionally restricts the
    run to the first ``k_active`` clusters as traced data — assignments
    land in ``[0, k_active)`` and match a native ``k=k_active`` run
    bitwise (centroid rows ``>= k_active`` are dead pad).

    ``mask`` (a traced (N,) participation mask, or None) excludes
    absent points from seeding, centroid means and reseeds while still
    assigning every point a cluster (see :func:`lloyd_step`); all-ones
    is bitwise the unmasked run.

    ``weights`` (a traced (N,) non-negative weight vector, or None)
    runs *weighted* k-means: ++ seeding draws scale to ``d * w`` and
    Lloyd means weight each row — the centroid-input mode, where the
    rows of ``X`` are themselves centroids from a lower tier and
    ``weights`` their member counts (the hierarchical coordinator's
    global tier). ``weights=None`` is bitwise the unweighted run;
    composes multiplicatively with ``mask``."""
    C0 = kmeans_pp_init(key, X, k, mask=mask, weights=weights)
    C = jax.lax.fori_loop(
        0, iters,
        lambda it, C: lloyd_step(X, C, k, use_pallas=use_pallas,
                                 k_active=k_active, mask=mask,
                                 weights=weights), C0)
    return C, _assign_fn(use_pallas, k_active)(X, C)
