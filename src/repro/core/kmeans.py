"""k-means over client distribution summaries (paper §III.B).

Lloyd iterations with k-means++ seeding, fully jit-able
(lax.fori_loop + static k). Empty clusters are re-seeded to *distinct*
far points — the j-th empty cluster takes the j-th farthest point from
its assigned centroid — so k clusters survive even with N=14 clients
and re-seeded centroids can actually separate (a single shared far
point would leave duplicate centroids forever).

The distance/assign step has two interchangeable implementations:
the jnp path below (the oracle) and the ``kmeans_assign`` Pallas kernel
(``use_pallas=True``) — one distance-matmul+argmin device program per
Lloyd iteration.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _pairwise_sq_dists(X, C):
    """(N, K) squared euclidean distances."""
    x2 = jnp.sum(X * X, axis=1, keepdims=True)
    c2 = jnp.sum(C * C, axis=1)[None, :]
    return jnp.maximum(x2 + c2 - 2.0 * X @ C.T, 0.0)


def kmeans_pp_init(key, X, k: int):
    """k-means++ seeding."""
    N = X.shape[0]
    keys = jax.random.split(key, k)
    idx0 = jax.random.randint(keys[0], (), 0, N)
    C = jnp.zeros((k, X.shape[1]), X.dtype).at[0].set(X[idx0])

    def body(i, C):
        # distances against the first i chosen centroids only
        valid = jnp.arange(k) < i
        dists = _pairwise_sq_dists(X, C)
        dists = jnp.where(valid[None, :], dists, jnp.inf)
        d = jnp.min(dists, axis=1)
        p = d / jnp.maximum(d.sum(), 1e-12)
        nxt = jax.random.choice(keys[i], N, p=p)
        return C.at[i].set(X[nxt])

    return jax.lax.fori_loop(1, k, body, C)


def assign(X, C):
    """Nearest-centroid assignment (the kmeans_assign kernel's math)."""
    return jnp.argmin(_pairwise_sq_dists(X, C), axis=1)


def _assign_fn(use_pallas: bool):
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.kmeans_assign
    return assign


def lloyd_step(X, C, k: int, *, use_pallas: bool = False):
    """One Lloyd iteration: assign, recompute means, reseed empties."""
    a = _assign_fn(use_pallas)(X, C)
    onehot = jax.nn.one_hot(a, k, dtype=X.dtype)             # (N, K)
    counts = onehot.sum(axis=0)                              # (K,)
    sums = onehot.T @ X                                      # (K, F)
    newC = sums / jnp.maximum(counts[:, None], 1.0)
    # empty clusters -> distinct far points: rank points by distance to
    # their current centroid (farthest first) and hand the j-th empty
    # cluster the j-th farthest point. Distance to the *assigned*
    # centroid equals the min pairwise distance, so reuse `a` instead
    # of a second full (N, K) distance matmul (the Pallas assign call
    # is opaque to XLA's CSE).
    diff = X - C[a]
    d = jnp.sum(diff * diff, axis=1)
    far_order = jnp.argsort(-d)                              # (N,)
    empty = counts == 0
    rank = jnp.clip(jnp.cumsum(empty.astype(jnp.int32)) - 1,
                    0, X.shape[0] - 1)                       # (K,)
    newC = jnp.where(empty[:, None], X[far_order[rank]], newC)
    return newC


def kmeans(key, X, k: int, iters: int = 20, *, use_pallas: bool = False):
    """Returns (centroids (k,F), assignments (N,))."""
    C0 = kmeans_pp_init(key, X, k)
    C = jax.lax.fori_loop(
        0, iters, lambda it, C: lloyd_step(X, C, k, use_pallas=use_pallas), C0)
    return C, _assign_fn(use_pallas)(X, C)
