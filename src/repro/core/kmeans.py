"""k-means over client distribution summaries (paper §III.B).

Lloyd iterations with k-means++ seeding, fully jit-able
(lax.fori_loop + static k). Empty clusters are re-seeded to the point
farthest from its assigned centroid, so k clusters survive even with
N=14 clients. The distance/assign step is the ``kmeans_assign`` Pallas
kernel's oracle path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _pairwise_sq_dists(X, C):
    """(N, K) squared euclidean distances."""
    x2 = jnp.sum(X * X, axis=1, keepdims=True)
    c2 = jnp.sum(C * C, axis=1)[None, :]
    return jnp.maximum(x2 + c2 - 2.0 * X @ C.T, 0.0)


def kmeans_pp_init(key, X, k: int):
    """k-means++ seeding."""
    N = X.shape[0]
    keys = jax.random.split(key, k)
    idx0 = jax.random.randint(keys[0], (), 0, N)
    C = jnp.zeros((k, X.shape[1]), X.dtype).at[0].set(X[idx0])

    def body(i, C):
        # distances against the first i chosen centroids only
        valid = jnp.arange(k) < i
        dists = _pairwise_sq_dists(X, C)
        dists = jnp.where(valid[None, :], dists, jnp.inf)
        d = jnp.min(dists, axis=1)
        p = d / jnp.maximum(d.sum(), 1e-12)
        nxt = jax.random.choice(keys[i], N, p=p)
        return C.at[i].set(X[nxt])

    return jax.lax.fori_loop(1, k, body, C)


def assign(X, C):
    """Nearest-centroid assignment (the kmeans_assign kernel's math)."""
    return jnp.argmin(_pairwise_sq_dists(X, C), axis=1)


def kmeans(key, X, k: int, iters: int = 20):
    """Returns (centroids (k,F), assignments (N,))."""
    N, F = X.shape
    C0 = kmeans_pp_init(key, X, k)

    def step(it, C):
        a = assign(X, C)
        onehot = jax.nn.one_hot(a, k, dtype=X.dtype)            # (N, K)
        counts = onehot.sum(axis=0)                              # (K,)
        sums = onehot.T @ X                                      # (K, F)
        newC = sums / jnp.maximum(counts[:, None], 1.0)
        # empty cluster -> farthest point from its current centroid
        d = jnp.min(_pairwise_sq_dists(X, C), axis=1)
        far = jnp.argmax(d)
        newC = jnp.where((counts[:, None] > 0), newC, X[far][None, :])
        return newC

    C = jax.lax.fori_loop(0, iters, step, C0)
    return C, assign(X, C)
