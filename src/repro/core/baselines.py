"""Table II baselines: centralized / local-only / FedAvg.

local-only and FedAvg reuse SwarmTrainer (aggregation="none"/"fedavg");
the centralized method pools every clinic's training data and trains a
single model — the privacy-ignoring upper bound.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, OptimizerConfig, SwarmConfig
from repro.core.swarm import SwarmTrainer, eval_client, make_batch
from repro.models.model import Model
from repro.optim.optimizers import make_optimizer
from repro.train.steps import make_eval_step, make_train_step


def train_centralized(model: Model, clients_data: List[dict],
                      opt_cfg: OptimizerConfig, key, *, steps: int,
                      batch_size: int = 32, lr=None):
    """Returns (params, per-client mean test accuracy — Eq. 3 applied to
    the single global model)."""
    X = np.concatenate([c["train"][0] for c in clients_data])
    y = np.concatenate([c["train"][1] for c in clients_data])
    rng = np.random.default_rng(0)

    opt = make_optimizer(opt_cfg)
    params = model.init(key)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    eval_fn = jax.jit(make_eval_step(model))
    lr = lr if lr is not None else opt_cfg.lr

    for _ in range(steps):
        idx = rng.integers(0, len(y), size=batch_size)
        params, opt_state, _ = step(params, opt_state,
                                    make_batch(model.cfg, X[idx], y[idx]), lr)

    accs = [eval_client(eval_fn, model.cfg, params, *c["test"])
            for c in clients_data]
    return params, float(np.mean(accs))


def run_method(method: str, model: Model, clients_data, swarm: SwarmConfig,
               opt_cfg: OptimizerConfig, key, *, batch_size: int = 16,
               verbose: bool = False):
    """One Table-II row. method in {centralized, local, fedavg, bso-sl}."""
    if method == "centralized":
        steps = swarm.rounds * max(1, swarm.local_epochs) * \
            int(np.ceil(np.mean([c["n_train"] for c in clients_data]) / batch_size)) \
            * len(clients_data)
        _, acc = train_centralized(model, clients_data, opt_cfg, key,
                                   steps=steps, batch_size=batch_size)
        return acc, None
    agg = {"local": "none", "fedavg": "fedavg", "bso-sl": "bso"}[method]
    tr = SwarmTrainer(model, clients_data, swarm, opt_cfg, key,
                      batch_size=batch_size, aggregation=agg)
    tr.fit(key, verbose=verbose)
    return tr.mean_accuracy("test"), tr
