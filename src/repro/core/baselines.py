"""Table II methods as thin slices of the sweep engine.

Since the method-axis redesign, all four paper methods (centralized /
local / FedAvg / BSO-SL) are parameterisations of the one fused round
in :mod:`repro.core.engine` (:class:`~repro.core.engine.MethodParams`).
This module is the host-facing surface over that axis:

* :func:`run_method`  — ONE scanned ``run_rounds`` program for one
  method's whole fit (the serial slice of the sweep; the parity
  reference ``tests/test_sweep.py`` pins against ``run_sweep`` rows).
* :func:`run_sweep_table` — the whole Table II axis as ONE vmapped
  ``run_sweep`` program sharing a single device-resident
  :class:`~repro.core.engine.SwarmData`.
* :func:`train_centralized` — the original pooled-data host loop, kept
  as the oracle for the engine's pooled-sampling centralized method.

Note the centralized budget change: the old host loop scaled its step
count by the number of clinics; the engine's centralized row rides the
same (rounds x local_steps) grid as every other method — N replicas
sampling the pooled dataset, averaged into one global model each round
— so the axis is a controlled same-budget, same-data comparison (the
property the SL-survey literature demands of Table II-style claims).
"""
from __future__ import annotations

import functools
from typing import List, NamedTuple, Sequence

import jax
import numpy as np

from repro.configs.base import OptimizerConfig, SwarmConfig
from repro.core.engine import (EngineConfig, RoundMetrics, SWEEP_METHODS,
                               SwarmData, SwarmState, jit_run_rounds,
                               jit_run_sweep, make_client_eval,
                               make_swarm_data, make_swarm_state,
                               make_sweep_config, make_sweep_state,
                               method_params, resolve_local_steps,
                               stack_eval_split)
from repro.core.swarm import eval_client, make_batch
from repro.models.model import Model
from repro.optim.optimizers import make_optimizer
from repro.train.steps import make_eval_step, make_train_step


def make_method_setup(model: Model, clients_data, swarm: SwarmConfig,
                      opt_cfg: OptimizerConfig, *, batch_size: int = 16,
                      lr=None, use_pallas: bool = False,
                      cfg: EngineConfig = None, data: SwarmData = None):
    """(EngineConfig, SwarmData) shared by every method/arch slice.
    Existing ``cfg``/``data`` pass through untouched, so repeated
    slices reuse one engine config (one compiled program) and one
    device-resident dataset — the sweep's whole point (table3 shares
    the data across architectures the same way)."""
    if cfg is None:
        opt = make_optimizer(opt_cfg)
        cfg = EngineConfig(
            model=model, opt=opt,
            local_steps=resolve_local_steps(swarm, clients_data, batch_size),
            batch_size=batch_size, lr=lr if lr is not None else opt_cfg.lr,
            aggregation="bso", n_clusters=swarm.n_clusters, p1=swarm.p1,
            p2=swarm.p2, kmeans_iters=swarm.kmeans_iters,
            use_pallas=use_pallas)
    if data is None:
        data = make_swarm_data(model.cfg, clients_data)
    return cfg, data


@functools.lru_cache(maxsize=None)
def _jit_client_eval(model: Model):
    return jax.jit(make_client_eval(model))


@functools.lru_cache(maxsize=None)
def _jit_sweep_eval(model: Model):
    return jax.jit(jax.vmap(make_client_eval(model), in_axes=(0, None)))


class MethodRun(NamedTuple):
    """One finished fit: final state + the (rounds,)-stacked metrics
    (method-stacked to (M, rounds) when produced by run_sweep_table)."""
    state: SwarmState
    metrics: RoundMetrics


def sweep_keys(key, methods: Sequence[str] = SWEEP_METHODS):
    """The per-method key schedule :func:`run_sweep_table` uses —
    the one copy, so serial parity runs reproduce row m exactly."""
    return jax.random.split(key, len(methods))


def run_method(method: str, model: Model, clients_data, swarm: SwarmConfig,
               opt_cfg: OptimizerConfig, key, *, batch_size: int = 16,
               verbose: bool = False, cfg: EngineConfig = None,
               data: SwarmData = None, test_stack=None):
    """One Table-II row. method in {centralized, local, fedavg, bso-sl}.

    The whole fit is ONE scanned device program
    (``run_rounds(..., method_params(method, N))``); the returned
    accuracy is Eq. 3 (mean per-client test accuracy) of the final
    per-client models. Pass ``cfg``/``data``/``test_stack`` from a
    previous call to share the device-resident dataset across slices.
    Returns ``(acc, MethodRun)``.
    """
    cfg, data = make_method_setup(model, clients_data, swarm, opt_cfg,
                                  batch_size=batch_size, cfg=cfg, data=data)
    state = make_swarm_state(model, cfg.opt, clients_data, key)
    state, ms = jit_run_rounds(state, data, cfg, swarm.rounds,
                               method_params(method, len(clients_data)))
    if verbose:
        for r, acc in enumerate(np.asarray(ms.mean_val_acc)):
            print(f"[{method}] round {r:3d} val_acc={acc:.4f}")
    if test_stack is None:
        test_stack = stack_eval_split(model.cfg, clients_data, "test")
    acc = float(np.mean(_jit_client_eval(model)(state.params, test_stack)))
    return acc, MethodRun(state, ms)


def run_sweep_table(model: Model, clients_data, swarm: SwarmConfig,
                    opt_cfg: OptimizerConfig, key, *,
                    methods: Sequence[str] = SWEEP_METHODS,
                    batch_size: int = 16, cfg: EngineConfig = None,
                    data: SwarmData = None, test_stack=None):
    """The whole Table II as ONE device program.

    ``key`` is split once into per-method keys (:func:`sweep_keys` —
    row m of the sweep is bitwise ``run_method(methods[m], ...,
    keys[m])``). Returns ``(accs: {method: Eq.3 test acc}, MethodRun)``
    where the MethodRun carries the (M,)-stacked final state and
    (M, rounds) metrics.
    """
    cfg, data = make_method_setup(model, clients_data, swarm, opt_cfg,
                                  batch_size=batch_size, cfg=cfg, data=data)
    keys = sweep_keys(key, methods)
    states = make_sweep_state(model, cfg.opt, clients_data, keys)
    sweep = make_sweep_config(len(clients_data), methods)
    states, ms = jit_run_sweep(states, data, cfg, sweep, swarm.rounds)
    if test_stack is None:
        test_stack = stack_eval_split(model.cfg, clients_data, "test")
    scores = np.asarray(_jit_sweep_eval(model)(states.params, test_stack))
    accs = {m: float(scores[i].mean()) for i, m in enumerate(methods)}
    return accs, MethodRun(states, ms)


def train_centralized(model: Model, clients_data: List[dict],
                      opt_cfg: OptimizerConfig, key, *, steps: int,
                      batch_size: int = 32, lr=None):
    """Host-loop pooled-data training — the oracle the engine's
    pooled-sampling centralized method miniaturises. Returns
    (params, per-client mean test accuracy — Eq. 3 applied to the
    single global model)."""
    X = np.concatenate([c["train"][0] for c in clients_data])
    y = np.concatenate([c["train"][1] for c in clients_data])
    rng = np.random.default_rng(0)

    opt = make_optimizer(opt_cfg)
    params = model.init(key)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    eval_fn = jax.jit(make_eval_step(model))
    lr = lr if lr is not None else opt_cfg.lr

    for _ in range(steps):
        idx = rng.integers(0, len(y), size=batch_size)
        params, opt_state, _ = step(params, opt_state,
                                    make_batch(model.cfg, X[idx], y[idx]), lr)

    accs = [eval_client(eval_fn, model.cfg, params, *c["test"])
            for c in clients_data]
    return params, float(np.mean(accs))
