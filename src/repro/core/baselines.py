"""Table II methods and hyper-parameter grids as thin slices of the
sweep/grid engine.

Since the method-axis redesign, all four paper methods (centralized /
local / FedAvg / BSO-SL) are parameterisations of the one fused round
in :mod:`repro.core.engine` (:class:`~repro.core.engine.MethodParams`),
and since the grid redesign the BSO knobs the paper fixes (k, p1, p2,
plus local-step/lr budgets) are too
(:class:`~repro.core.engine.GridPoint`). This module is the
host-facing surface over those axes. Which entry point to use:

* :func:`run_method`  — ONE paper method, one scanned ``run_rounds``
  program for the whole fit. Use it when you want a single Table-II
  row (or the serial parity reference for a sweep row —
  ``tests/test_sweep.py`` pins sweep row m == ``run_method`` bitwise).
* :func:`run_sweep_table` — the whole Table II *method axis* as ONE
  vmapped ``run_sweep`` program sharing a single device-resident
  :class:`~repro.core.engine.SwarmData`. Use it whenever you need two
  or more methods: M methods cost one compile and one dispatch.
* :func:`run_grid_table` — a *hyper-parameter grid* (k / p1 / p2 /
  local_steps / lr axes, any method) as ONE vmapped ``run_grid``
  program. Use it for ablations: |grid| serial fits collapse into one
  executable (``BENCH_grid.json`` records the collapse).
* :func:`run_grid_point` — one grid point as a serial scanned program:
  the parity oracle for ``run_grid_table`` rows
  (``tests/test_grid.py``) and the right call for a one-off
  non-default hyper-parameter fit.
* :func:`train_centralized` — the original pooled-data host loop, kept
  as the oracle for the engine's pooled-sampling centralized method.

Note the centralized budget change: the old host loop scaled its step
count by the number of clinics; the engine's centralized row rides the
same (rounds x local_steps) grid as every other method — N replicas
sampling the pooled dataset, averaged into one global model each round
— so the axis is a controlled same-budget, same-data comparison (the
property the SL-survey literature demands of Table II-style claims).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, NamedTuple, Sequence

import jax
import numpy as np

from repro.configs.base import OptimizerConfig, SwarmConfig
from repro.core.engine import (EngineConfig, RoundMetrics, SWEEP_METHODS,
                               SwarmData, SwarmState, grid_axes, grid_point,
                               jit_run_grid, jit_run_rounds, jit_run_sweep,
                               make_bucketed_swarm_data, make_client_eval,
                               make_grid_config, make_grid_state,
                               make_swarm_data, make_swarm_state,
                               make_sweep_config, make_sweep_state,
                               method_params, resolve_local_steps,
                               stack_eval_split)
from repro.core.swarm import eval_client, make_batch
from repro.models.model import Model
from repro.optim.optimizers import make_optimizer
from repro.train.steps import make_eval_step, make_train_step


def make_method_setup(model: Model, clients_data, swarm: SwarmConfig,
                      opt_cfg: OptimizerConfig, *, batch_size: int = 16,
                      lr=None, use_pallas: bool = False,
                      cfg: EngineConfig = None, data: SwarmData = None,
                      layout: str = "rect"):
    """(EngineConfig, SwarmData) shared by every method/arch slice.
    Existing ``cfg``/``data`` pass through untouched, so repeated
    slices reuse one engine config (one compiled program) and one
    device-resident dataset — the sweep's whole point (table3 shares
    the data across architectures the same way).

    ``layout`` picks the device data layout when ``data`` is built
    here: ``"rect"`` is the pad-to-global-max
    :class:`~repro.core.engine.SwarmData`, ``"bucketed"`` the ragged
    :class:`~repro.core.engine.BucketedSwarmData` (size-bucketed pads;
    bitwise the same results — see ``tests/test_bucket.py``)."""
    if cfg is None:
        opt = make_optimizer(opt_cfg)
        cfg = EngineConfig(
            model=model, opt=opt,
            local_steps=resolve_local_steps(swarm, clients_data, batch_size),
            batch_size=batch_size, lr=lr if lr is not None else opt_cfg.lr,
            aggregation="bso", n_clusters=swarm.n_clusters, p1=swarm.p1,
            p2=swarm.p2, kmeans_iters=swarm.kmeans_iters,
            use_pallas=use_pallas)
    if data is None:
        if layout == "bucketed":
            data = make_bucketed_swarm_data(model.cfg, clients_data)
        elif layout == "rect":
            data = make_swarm_data(model.cfg, clients_data)
        else:
            raise ValueError(f"unknown layout {layout!r} "
                             "(one of 'rect', 'bucketed')")
    return cfg, data


@functools.lru_cache(maxsize=None)
def _jit_client_eval(model: Model):
    return jax.jit(make_client_eval(model))


@functools.lru_cache(maxsize=None)
def _jit_sweep_eval(model: Model):
    return jax.jit(jax.vmap(make_client_eval(model), in_axes=(0, None)))


class MethodRun(NamedTuple):
    """One finished fit: final state + the (rounds,)-stacked metrics
    (method-stacked to (M, rounds) when produced by run_sweep_table)."""
    state: SwarmState
    metrics: RoundMetrics


def sweep_keys(key, methods: Sequence = SWEEP_METHODS):
    """The per-row key schedule :func:`run_sweep_table` and
    :func:`run_grid_table` use (``methods`` is any row sequence —
    method names or grid-point specs; only its length matters) — the
    one copy, so serial parity runs reproduce row m exactly."""
    return jax.random.split(key, len(methods))


def run_method(method: str, model: Model, clients_data, swarm: SwarmConfig,
               opt_cfg: OptimizerConfig, key, *, batch_size: int = 16,
               verbose: bool = False, cfg: EngineConfig = None,
               data: SwarmData = None, test_stack=None):
    """One Table-II row. method in {centralized, local, fedavg, bso-sl}.

    The whole fit is ONE scanned device program
    (``run_rounds(..., method_params(method, N))``); the returned
    accuracy is Eq. 3 (mean per-client test accuracy) of the final
    per-client models. Pass ``cfg``/``data``/``test_stack`` from a
    previous call to share the device-resident dataset across slices.
    Returns ``(acc, MethodRun)``.
    """
    cfg, data = make_method_setup(model, clients_data, swarm, opt_cfg,
                                  batch_size=batch_size, cfg=cfg, data=data)
    state = make_swarm_state(model, cfg.opt, clients_data, key)
    state, ms = jit_run_rounds(state, data, cfg, swarm.rounds,
                               method_params(method, len(clients_data)))
    if verbose:
        for r, acc in enumerate(np.asarray(ms.mean_val_acc)):
            print(f"[{method}] round {r:3d} val_acc={acc:.4f}")
    if test_stack is None:
        test_stack = stack_eval_split(model.cfg, clients_data, "test")
    acc = float(np.mean(_jit_client_eval(model)(state.params, test_stack)))
    return acc, MethodRun(state, ms)


def run_sweep_table(model: Model, clients_data, swarm: SwarmConfig,
                    opt_cfg: OptimizerConfig, key, *,
                    methods: Sequence[str] = SWEEP_METHODS,
                    batch_size: int = 16, cfg: EngineConfig = None,
                    data: SwarmData = None, test_stack=None):
    """The whole Table II as ONE device program.

    ``key`` is split once into per-method keys (:func:`sweep_keys` —
    row m of the sweep is bitwise ``run_method(methods[m], ...,
    keys[m])``). Returns ``(accs: {method: Eq.3 test acc}, MethodRun)``
    where the MethodRun carries the (M,)-stacked final state and
    (M, rounds) metrics.
    """
    cfg, data = make_method_setup(model, clients_data, swarm, opt_cfg,
                                  batch_size=batch_size, cfg=cfg, data=data)
    keys = sweep_keys(key, methods)
    states = make_sweep_state(model, cfg.opt, clients_data, keys)
    sweep = make_sweep_config(len(clients_data), methods)
    states, ms = jit_run_sweep(states, data, cfg, sweep, swarm.rounds)
    if test_stack is None:
        test_stack = stack_eval_split(model.cfg, clients_data, "test")
    scores = np.asarray(_jit_sweep_eval(model)(states.params, test_stack))
    accs = {m: float(scores[i].mean()) for i, m in enumerate(methods)}
    return accs, MethodRun(states, ms)


def run_grid_point(spec: dict, model: Model, clients_data,
                   swarm: SwarmConfig, opt_cfg: OptimizerConfig, key, *,
                   batch_size: int = 16, cfg: EngineConfig = None,
                   data: SwarmData = None, test_stack=None):
    """One hyper-parameter point as a serial scanned program.

    ``spec`` is a :func:`~repro.core.engine.grid_point` keyword dict
    (e.g. ``{"k": 2, "p1": 1.0}``; empty = the paper point). The fit is
    ONE ``run_rounds`` program whose static maxima come from ``cfg``,
    so it is the bitwise serial slice of the corresponding
    :func:`run_grid_table` row — the grid parity oracle
    (``tests/test_grid.py``). Returns ``(acc, MethodRun)`` like
    :func:`run_method`.
    """
    cfg, data = make_method_setup(model, clients_data, swarm, opt_cfg,
                                  batch_size=batch_size, cfg=cfg, data=data)
    point = grid_point(cfg, len(clients_data), **spec)
    state = make_swarm_state(model, cfg.opt, clients_data, key)
    state, ms = jit_run_rounds(state, data, cfg, swarm.rounds, point)
    if test_stack is None:
        test_stack = stack_eval_split(model.cfg, clients_data, "test")
    acc = float(np.mean(_jit_client_eval(model)(state.params, test_stack)))
    return acc, MethodRun(state, ms)


def run_grid_table(model: Model, clients_data, swarm: SwarmConfig,
                   opt_cfg: OptimizerConfig, key, *,
                   axes: dict = None, specs: Sequence[dict] = None,
                   batch_size: int = 16, cfg: EngineConfig = None,
                   data: SwarmData = None, test_stack=None):
    """A whole hyper-parameter ablation as ONE device program —
    :func:`run_sweep_table`'s sibling for the grid axis.

    Pass either ``axes`` (named axes, expanded row-major via
    :func:`~repro.core.engine.grid_axes`, e.g.
    ``axes={"k": (1, 2, 3), "p1": (0.9, 1.0)}`` — the churn scenario
    axes ``dropout`` / ``stale_decay`` / ``churn_mask`` ride the same
    surface, so a dropout-robustness sweep is one call) or an explicit
    ``specs`` list of grid-point keyword dicts. The engine statics in
    ``cfg`` (``n_clusters``, ``local_steps``) are the grid's pads, so
    every axis value must stay within them; when ``cfg`` is built here,
    its ``n_clusters`` is raised to the largest ``k`` in the grid and
    its step budget to the largest ``local_steps`` (over the
    swarm-resolved default).

    ``key`` splits once into per-point keys (:func:`sweep_keys` — row g
    is bitwise :func:`run_grid_point` of ``specs[g]`` with ``keys[g]``;
    grids with heterogeneous ``local_steps`` ride the sorted scan
    schedule, where the contract weakens to allclose ~1 ulp — see
    :func:`~repro.core.engine._run_grid_scheduled`).
    Returns ``(results, MethodRun)`` where ``results`` is a list of
    ``{**spec, "acc": Eq.3 test acc}`` rows in grid order and the
    MethodRun carries the (G,)-stacked final state and (G, rounds)
    metrics.
    """
    if (axes is None) == (specs is None):
        raise ValueError("pass exactly one of axes= or specs=")
    if specs is None:
        specs = grid_axes(**axes)
    rows = specs
    if cfg is None:
        # pin every row's k/local_steps to the CALLER's statics before
        # raising the pads to the grid maxima — otherwise a spec that
        # omits a raised knob would silently inherit the raised value
        # instead of the paper point, breaking the run_grid_point
        # parity contract. (With an explicit cfg the statics ARE the
        # contract and rows inherit them unchanged.)
        base_steps = resolve_local_steps(swarm, clients_data, batch_size)
        rows = [{"k": swarm.n_clusters, "local_steps": base_steps, **s}
                for s in specs]
        # raise-only: the step pad fixes the PRNG split count, so
        # shrinking it below the caller's statics would break the
        # run_grid_point-with-the-same-swarm oracle
        swarm = dataclasses.replace(
            swarm,
            n_clusters=max(swarm.n_clusters,
                           *(int(r["k"]) for r in rows)),
            local_steps=max(base_steps,
                            *(int(r["local_steps"]) for r in rows)))
    cfg, data = make_method_setup(model, clients_data, swarm, opt_cfg,
                                  batch_size=batch_size, cfg=cfg, data=data)
    keys = sweep_keys(key, specs)
    states = make_grid_state(model, cfg.opt, clients_data, keys)
    grid = make_grid_config(cfg, len(clients_data), rows)
    # heterogeneous step budgets ride the sorted scan schedule (rows
    # exit the scan at their own budget instead of paying the static
    # max as masked no-ops); uniform grids keep the plain masked path.
    # Churn grids always keep the masked path — the schedule's prefix
    # segments assume every row trains every client (run_grid raises
    # on the combination)
    has_churn = any(k in r for r in rows
                    for k in ("dropout", "stale_decay", "churn_mask"))
    row_steps = tuple(int(r.get("local_steps", cfg.local_steps))
                      for r in rows)
    schedule = (row_steps if min(row_steps) < cfg.local_steps
                and not has_churn else None)
    states, ms = jit_run_grid(states, data, cfg, grid, swarm.rounds,
                              schedule)
    if test_stack is None:
        test_stack = stack_eval_split(model.cfg, clients_data, "test")
    scores = np.asarray(_jit_sweep_eval(model)(states.params, test_stack))
    results = [{**spec, "acc": float(scores[g].mean())}
               for g, spec in enumerate(specs)]
    return results, MethodRun(states, ms)


def train_centralized(model: Model, clients_data: List[dict],
                      opt_cfg: OptimizerConfig, key, *, steps: int,
                      batch_size: int = 32, lr=None):
    """Host-loop pooled-data training — the oracle the engine's
    pooled-sampling centralized method miniaturises. Returns
    (params, per-client mean test accuracy — Eq. 3 applied to the
    single global model)."""
    X = np.concatenate([c["train"][0] for c in clients_data])
    y = np.concatenate([c["train"][1] for c in clients_data])
    rng = np.random.default_rng(0)

    opt = make_optimizer(opt_cfg)
    params = model.init(key)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    eval_fn = jax.jit(make_eval_step(model))
    lr = lr if lr is not None else opt_cfg.lr

    for _ in range(steps):
        idx = rng.integers(0, len(y), size=batch_size)
        params, opt_state, _ = step(params, opt_state,
                                    make_batch(model.cfg, X[idx], y[idx]), lr)

    accs = [eval_client(eval_fn, model.cfg, params, *c["test"])
            for c in clients_data]
    return params, float(np.mean(accs))
