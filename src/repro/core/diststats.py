"""Parameter-distribution summaries (paper §III.B).

Each client uploads only the *distribution* of its model parameters —
per-tensor (mean, variance, size) under the paper's Gaussian assumption —
never the parameters themselves. The resulting feature vector has
O(#tensors) dimensions (hundreds) instead of O(#params) (millions to
10^12), which is both the privacy and the communication-efficiency
argument of BSO-SL.

Note (DESIGN.md §8): the paper says "mean and covariance"; a full
covariance is O(n^2) and contradicts the paper's own communication
claim, so this is the diagonal (per-tensor variance) reading.

The reduction itself is a memory-bound pass over every parameter — on
TPU it is served by the ``param_stats`` / ``param_stats_batched``
Pallas kernels (``repro/kernels/param_stats.py``); the jnp paths below
are the oracles and the CPU/lowering path. The coordinator consumes the
whole swarm at once via ``swarm_distribution_matrix`` — one jit'd pass
over the client-stacked pytree, not a per-client host loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_paths_and_leaves


def tensor_stats(x: jnp.ndarray):
    """(mean, var) of one tensor in fp32."""
    xf = x.astype(jnp.float32).reshape(-1)
    mean = jnp.mean(xf)
    var = jnp.var(xf)
    return mean, var


# client-axis oracle: per-client (mean, var) of a stacked (N, ...) leaf
batched_tensor_stats = jax.vmap(tensor_stats)


def param_distribution(params, *, use_pallas: bool = False):
    """Returns a feature vector (2 * n_tensors,) of per-tensor
    [mean, log1p(var)] pairs in a deterministic path order.

    ``log1p(var)`` rather than raw variance so k-means distances are not
    dominated by a single high-variance tensor (scale robustness).

    One client is the N=1 case of the swarm feature pass, so this is
    row 0 of ``_swarm_features`` on a singleton-stacked tree — a single
    copy of the feature logic that cannot drift from the batched path.
    """
    stacked = jax.tree.map(lambda x: x[None], params)
    return _swarm_features(stacked, use_pallas=use_pallas)[0]


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def _swarm_features(stacked_params, *, use_pallas: bool):
    if use_pallas:
        from repro.kernels import ops as kops
        stat_fn = kops.param_stats_batched
    else:
        stat_fn = batched_tensor_stats
    pairs = sorted(tree_paths_and_leaves(stacked_params), key=lambda kv: kv[0])
    cols = []
    for _, leaf in pairs:
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            continue
        m, v = stat_fn(leaf)
        cols.append(m)
        cols.append(jnp.log1p(v))
    return jnp.stack(cols, axis=1)                       # (N, 2*T)


def swarm_distribution_matrix(stacked_params, n_clients: int = None, *,
                              use_pallas: bool = False):
    """Feature matrix (n_clients, F) from a client-stacked pytree —
    what the coordinator receives each round.

    All (client, tensor) [mean, log1p(var)] features are computed in a
    single jit'd pass over the stacked pytree: the jnp path vmaps
    ``tensor_stats`` over the client axis, the Pallas path reduces each
    stacked leaf on an (N, n_blocks) grid — one device program for the
    whole swarm instead of O(N·T) host dispatches."""
    if n_clients is not None:
        lead = jax.tree.leaves(stacked_params)[0].shape[0]
        if lead != n_clients:
            raise ValueError(
                f"stacked_params has client axis {lead} but n_clients="
                f"{n_clients}; slice the pytree to the requested subset")
    return _swarm_features(stacked_params, use_pallas=use_pallas)


def swarm_distribution_matrix_loop(stacked_params, n_clients: int, *,
                                   use_pallas: bool = False):
    """The pre-batching coordinator: a host loop over clients with a
    per-tensor eager dispatch per stat — O(N·T) tiny device programs.
    Kept as the parity oracle for the batched path and as the 'before'
    side of ``benchmarks/cluster_ablation.coordinator_bench``.

    Deliberately does NOT share ``_swarm_features``: an oracle that
    routes through the code it checks can't catch bugs in the shared
    feature logic, and a baseline that jit-fuses per client would
    misrepresent the old dispatch count."""
    if use_pallas:
        from repro.kernels import ops as kops
        stat_fn = kops.param_stats
    else:
        stat_fn = tensor_stats
    rows = []
    for i in range(n_clients):
        client = jax.tree.map(lambda x: x[i], stacked_params)
        pairs = sorted(tree_paths_and_leaves(client), key=lambda kv: kv[0])
        feats = []
        for _, leaf in pairs:
            if not jnp.issubdtype(leaf.dtype, jnp.floating):
                continue
            m, v = stat_fn(leaf)
            feats.append(m)
            feats.append(jnp.log1p(v))
        rows.append(jnp.stack(feats))
    return jnp.stack(rows)


def upload_bytes(params) -> int:
    """Bytes a client uploads per round under BSO-SL (the stats)."""
    n_tensors = sum(1 for _, l in tree_paths_and_leaves(params)
                    if jnp.issubdtype(l.dtype, jnp.floating))
    return 2 * n_tensors * 4


def full_params_bytes(params) -> int:
    """Bytes a client would upload under FedAvg / blockchain SL."""
    return int(sum(l.size * l.dtype.itemsize for _, l in tree_paths_and_leaves(params)))
