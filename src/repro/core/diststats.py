"""Parameter-distribution summaries (paper §III.B).

Each client uploads only the *distribution* of its model parameters —
per-tensor (mean, variance, size) under the paper's Gaussian assumption —
never the parameters themselves. The resulting feature vector has
O(#tensors) dimensions (hundreds) instead of O(#params) (millions to
10^12), which is both the privacy and the communication-efficiency
argument of BSO-SL.

Note (DESIGN.md §8): the paper says "mean and covariance"; a full
covariance is O(n^2) and contradicts the paper's own communication
claim, so this is the diagonal (per-tensor variance) reading.

The reduction itself is a memory-bound pass over every parameter — on
TPU it is served by the ``param_stats`` Pallas kernel
(``repro/kernels/param_stats.py``); the jnp path below is the oracle
and the CPU/lowering path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.tree import tree_paths_and_leaves


def tensor_stats(x: jnp.ndarray):
    """(mean, var) of one tensor in fp32."""
    xf = x.astype(jnp.float32).reshape(-1)
    mean = jnp.mean(xf)
    var = jnp.var(xf)
    return mean, var


def param_distribution(params, *, use_pallas: bool = False):
    """Returns a feature vector (2 * n_tensors,) of per-tensor
    [mean, log1p(var)] pairs in a deterministic path order.

    ``log1p(var)`` rather than raw variance so k-means distances are not
    dominated by a single high-variance tensor (scale robustness).
    """
    if use_pallas:
        from repro.kernels import ops as kops
        stat_fn = kops.param_stats
    else:
        stat_fn = tensor_stats
    pairs = sorted(tree_paths_and_leaves(params), key=lambda kv: kv[0])
    feats = []
    for _, leaf in pairs:
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            continue
        m, v = stat_fn(leaf)
        feats.append(m)
        feats.append(jnp.log1p(v))
    return jnp.stack(feats)


def swarm_distribution_matrix(stacked_params, n_clients: int, *,
                              use_pallas: bool = False):
    """Feature matrix (n_clients, F) from a client-stacked pytree —
    what the coordinator receives each round."""
    return _loop_features(stacked_params, n_clients, use_pallas)


def _loop_features(stacked_params, n_clients, use_pallas):
    # vmap over pytree indexing is awkward with sorted paths; a host loop
    # over N<=hundreds of clients is the realistic coordinator behaviour.
    rows = []
    for i in range(n_clients):
        client = jax.tree.map(lambda x: x[i], stacked_params)
        rows.append(param_distribution(client, use_pallas=use_pallas))
    return jnp.stack(rows)


def upload_bytes(params) -> int:
    """Bytes a client uploads per round under BSO-SL (the stats)."""
    n_tensors = sum(1 for _, l in tree_paths_and_leaves(params)
                    if jnp.issubdtype(l.dtype, jnp.floating))
    return 2 * n_tensors * 4


def full_params_bytes(params) -> int:
    """Bytes a client would upload under FedAvg / blockchain SL."""
    return int(sum(l.size * l.dtype.itemsize for _, l in tree_paths_and_leaves(params)))
