"""Stateful host wrapper over the functional round engine (sim regime).

Since the engine redesign, all round logic lives in
:mod:`repro.core.engine`: an explicit :class:`~repro.core.engine.SwarmState`
pytree and the pure ``swarm_round(state, data, cfg)`` function, jit'd
into ONE device program per round (and scannable over rounds via
``run_rounds``). :class:`SwarmTrainer` is the thin stateful shell that
remains for host-driven use — it owns a ``SwarmState``, advances it one
engine call per round, and keeps the familiar surface:

  ``round`` / ``fit``        — advance the protocol, appending
                               :class:`RoundLog` entries to ``history``
  ``fit_scanned``            — the same rounds as one scanned program
  ``client_scores``          — per-client masked accuracy on any split
  ``aggregation`` mode       — "bso" (full §III round), "fedavg"
                               (federated baseline), "none" (isolation)

(The centralized baseline pools data and is in baselines.py.) Batch
sampling, the brain-storm decision, k-means and Eq. 2 all execute
on-device inside the engine program; the only host-side residue is the
conversion of per-round metrics into ``RoundLog``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import OptimizerConfig, SwarmConfig
from repro.core.engine import (EngineConfig, RoundMetrics, SwarmState,
                               jit_run_rounds, jit_swarm_round,
                               make_batch, make_client_eval, make_swarm_data,
                               make_swarm_state, pad_eval_split,
                               resolve_local_steps, stack_eval_split)
from repro.models.model import Model
from repro.optim.optimizers import make_optimizer
from repro.train.steps import make_eval_step


def eval_client(eval_fn, cfg, params, X, y, batch: int = 64) -> float:
    """Masked fixed-shape evaluation of ONE client (pads with label=-1).

    Kept for the centralized baseline and as the parity oracle for the
    engine's vmapped client-axis eval (:func:`make_client_eval`)."""
    n = len(y)
    correct, total = 0.0, 0
    for s in range(0, n, batch):
        k = len(y[s:s + batch])
        xb, yb = pad_eval_split(X[s:s + batch], y[s:s + batch], batch)
        m = eval_fn(params, make_batch(cfg, xb, yb))
        correct += float(m["acc"]) * k
        total += k
    return correct / max(total, 1)


@dataclass
class RoundLog:
    round: int
    mean_val_acc: float
    assignments: np.ndarray
    centers: np.ndarray
    events: List[str]
    train_loss: float


def _round_log(r: int, m: RoundMetrics) -> RoundLog:
    events = (["replace"] * int(m.n_replaced) + ["swap"] * int(m.n_swapped))
    return RoundLog(r, float(m.mean_val_acc), np.asarray(m.assignments),
                    np.asarray(m.centers), events, float(m.train_loss))


class SwarmTrainer:
    def __init__(self, model: Model, clients_data: List[dict],
                 swarm: SwarmConfig, opt_cfg: OptimizerConfig,
                 key, *, batch_size: int = 16, aggregation: str = "bso",
                 lr: Optional[float] = None, reset_opt_each_round: bool = False,
                 use_pallas: bool = False):
        assert aggregation in ("bso", "fedavg", "none")
        self.model = model
        self.cfg = model.cfg
        self.data = clients_data
        self.swarm = swarm
        self.n = len(clients_data)
        self.batch_size = batch_size
        self.aggregation = aggregation
        self.lr = lr if lr is not None else opt_cfg.lr
        self.opt = make_optimizer(opt_cfg)
        self.n_samples = np.array([c["n_train"] for c in clients_data],
                                  np.float32)

        self.engine_cfg = EngineConfig(
            model=model, opt=self.opt, local_steps=self._local_steps(),
            batch_size=batch_size, lr=self.lr, aggregation=aggregation,
            n_clusters=swarm.n_clusters, p1=swarm.p1, p2=swarm.p2,
            kmeans_iters=swarm.kmeans_iters, use_pallas=use_pallas,
            reset_opt_each_round=reset_opt_each_round)
        self.swarm_data = make_swarm_data(self.cfg, clients_data)
        self.state: SwarmState = make_swarm_state(model, self.opt,
                                                  clients_data, key)

        # _eval stays public-ish: eval_client(tr._eval, ...) is the
        # per-client parity oracle used by tests and coordinator_bench
        self._eval = jax.jit(make_eval_step(model))
        self._veval = jax.jit(make_client_eval(model))
        # the engine data already holds the device-resident val stack;
        # seed the split cache so client_scores("val") reuses it
        self._eval_splits: Dict[str, dict] = {"val": self.swarm_data.val}
        self.history: List[RoundLog] = []

    # engine state passthroughs (the state pytree is the truth)
    @property
    def params(self):
        return self.state.params

    @property
    def opt_state(self):
        return self.state.opt_state

    # ---------------------------------------------------------------- local
    def _local_steps(self) -> int:
        return resolve_local_steps(self.swarm, self.data, self.batch_size)

    # ----------------------------------------------------------------- eval
    def client_scores(self, split: str = "val") -> np.ndarray:
        """Per-client masked accuracy — ONE vmapped device program over
        the client axis per split (eval data is static, so the
        device-resident stack is built once per split)."""
        if split not in self._eval_splits:
            self._eval_splits[split] = stack_eval_split(self.cfg, self.data,
                                                        split)
        scores = self._veval(self.state.params, self._eval_splits[split])
        return np.asarray(scores, np.float32)

    def mean_accuracy(self, split: str = "test") -> float:
        """Paper Eq. 3: average of per-client accuracy."""
        return float(self.client_scores(split).mean())

    # ---------------------------------------------------------------- round
    def round(self, r: int, key) -> RoundLog:
        """One protocol round == one engine program dispatch."""
        # the engine donates its state buffers; copy the caller's key so
        # their array survives the donation (keys are reusable here)
        state = self.state._replace(key=jnp.copy(key))
        self.state, m = jit_swarm_round(state, self.swarm_data,
                                        self.engine_cfg)
        log = _round_log(r, m)
        self.history.append(log)
        return log

    def fit(self, key, rounds: Optional[int] = None, verbose: bool = False):
        """Round-by-round fit on ONE key schedule: the caller's key
        seeds the engine chain once and every round's keys derive
        in-program from the carried state key — the identical schedule
        :meth:`fit_scanned`'s scan advances, so the two are bitwise
        interchangeable (``tests/test_sweep.py`` pins this)."""
        rounds = rounds or self.swarm.rounds
        self.state = self.state._replace(key=jnp.copy(jnp.asarray(key)))
        start = len(self.history)
        for r in range(start, start + rounds):
            self.state, m = jit_swarm_round(self.state, self.swarm_data,
                                            self.engine_cfg)
            log = _round_log(r, m)
            self.history.append(log)
            if verbose:
                print(f"[{self.aggregation}] round {r:3d} "
                      f"val_acc={log.mean_val_acc:.4f} loss={log.train_loss:.4f} "
                      + ("; ".join(log.events) if log.events else ""))
        return self.history

    def fit_scanned(self, key, rounds: Optional[int] = None):
        """The same rounds as :meth:`fit`, but scanned into ONE device
        program (``engine.run_rounds``) — no per-round host dispatch."""
        rounds = rounds or self.swarm.rounds
        state = self.state._replace(key=jnp.copy(key))
        self.state, ms = jit_run_rounds(state, self.swarm_data,
                                        self.engine_cfg, rounds)
        start = len(self.history)
        for i in range(rounds):
            self.history.append(
                _round_log(start + i, jax.tree.map(lambda x: x[i], ms)))
        return self.history
