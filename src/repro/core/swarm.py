"""Swarm orchestration (sim regime): N clients as a stacked pytree.

One :class:`SwarmTrainer` runs all four methods of the paper's Table II
via ``aggregation`` mode:

  "bso"     — the full BSO-SL round (§III): local training → distribution
              upload → k-means clustering → brain-storm aggregation.
  "fedavg"  — global FedAvg every round (the federated baseline).
  "none"    — local training only (the isolation baseline).

(The centralized baseline pools data and is in baselines.py.)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, OptimizerConfig, SwarmConfig
from repro.core.aggregation import cluster_fedavg
from repro.core.bso import brain_storm
from repro.core.diststats import swarm_distribution_matrix
from repro.core.kmeans import kmeans
from repro.models.model import Model
from repro.optim.optimizers import make_optimizer
from repro.train.steps import make_eval_step, make_train_step


def make_batch(cfg: ModelConfig, X, y):
    if cfg.family == "cnn":
        return {"images": jnp.asarray(X), "labels": jnp.asarray(y)}
    return {"tokens": jnp.asarray(X), "labels": jnp.asarray(y)}


def _sample_batch(rng, X, y, batch):
    idx = rng.integers(0, len(y), size=batch)
    return X[idx], y[idx]


def pad_eval_split(X, y, n_to: int):
    """Pad an eval slice to ``n_to`` rows: zero inputs, label=-1 rows
    (the loss/accuracy mask) — the one copy of the masking convention
    shared by the per-client loop and the stacked vmapped eval."""
    pad = n_to - len(y)
    if pad:
        X = np.concatenate([X, np.zeros((pad,) + X.shape[1:], X.dtype)])
        y = np.concatenate([y, -np.ones((pad,) + y.shape[1:], y.dtype)])
    return X, y


def eval_client(eval_fn, cfg, params, X, y, batch: int = 64) -> float:
    """Masked fixed-shape evaluation of ONE client (pads with label=-1).

    Kept for the centralized baseline and as the parity oracle for the
    vmapped client-axis eval in :meth:`SwarmTrainer.client_scores`."""
    n = len(y)
    correct, total = 0.0, 0
    for s in range(0, n, batch):
        k = len(y[s:s + batch])
        xb, yb = pad_eval_split(X[s:s + batch], y[s:s + batch], batch)
        m = eval_fn(params, make_batch(cfg, xb, yb))
        correct += float(m["acc"]) * k
        total += k
    return correct / max(total, 1)


@dataclass
class RoundLog:
    round: int
    mean_val_acc: float
    assignments: np.ndarray
    centers: np.ndarray
    events: List[str]
    train_loss: float


class SwarmTrainer:
    def __init__(self, model: Model, clients_data: List[dict],
                 swarm: SwarmConfig, opt_cfg: OptimizerConfig,
                 key, *, batch_size: int = 16, aggregation: str = "bso",
                 lr: Optional[float] = None, reset_opt_each_round: bool = False,
                 use_pallas: bool = False):
        assert aggregation in ("bso", "fedavg", "none")
        self.reset_opt_each_round = reset_opt_each_round
        self.model = model
        self.cfg = model.cfg
        self.data = clients_data
        self.swarm = swarm
        self.n = len(clients_data)
        self.batch_size = batch_size
        self.aggregation = aggregation
        self.use_pallas = use_pallas
        self.lr = lr if lr is not None else opt_cfg.lr
        self.opt = make_optimizer(opt_cfg)

        keys = jax.random.split(key, self.n)
        self.params = jax.vmap(model.init)(keys)
        self.opt_state = jax.vmap(self.opt.init)(self.params)
        step = make_train_step(model, self.opt)
        # params/opt_state are donated: each local step and the round's
        # aggregation update the swarm state in place instead of copying
        # the whole stacked pytree every dispatch
        self._vstep = jax.jit(jax.vmap(step, in_axes=(0, 0, 0, None)),
                              donate_argnums=(0, 1))
        eval_step = make_eval_step(model)
        self._eval = jax.jit(eval_step)

        def client_eval(params, batches):
            # scan over fixed 64-sample microbatches so the activation
            # footprint stays O(N * eval_batch) regardless of split
            # size; still ONE device program for the whole swarm
            def one(carry, bt):
                hits, tot = carry
                m = eval_step(params, bt)
                valid = jnp.sum(bt["labels"] >= 0).astype(jnp.float32)
                return (hits + m["acc"] * valid, tot + valid), None

            (hits, tot), _ = jax.lax.scan(
                one, (jnp.float32(0.0), jnp.float32(0.0)), batches)
            return hits / jnp.maximum(tot, 1.0)

        self._veval = jax.jit(jax.vmap(client_eval))
        self._eval_splits: Dict[str, dict] = {}
        self._agg = jax.jit(cluster_fedavg, static_argnames=("k",),
                            donate_argnums=(0,))
        self._kmeans = jax.jit(
            kmeans, static_argnames=("k", "iters", "use_pallas"))
        self.np_rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))
        self.n_samples = np.array([c["n_train"] for c in clients_data], np.float32)
        self.history: List[RoundLog] = []

    # ---------------------------------------------------------------- local
    def _local_steps(self) -> int:
        if self.swarm.local_steps is not None:
            return self.swarm.local_steps
        steps_per_epoch = int(np.ceil(self.n_samples.mean() / self.batch_size))
        return max(1, self.swarm.local_epochs * steps_per_epoch)

    def local_train(self):
        last = None
        for _ in range(self._local_steps()):
            xs, ys = [], []
            for c in self.data:
                X, y = c["train"]
                xb, yb = _sample_batch(self.np_rng, X, y, self.batch_size)
                xs.append(xb)
                ys.append(yb)
            batch = make_batch(self.cfg, np.stack(xs), np.stack(ys))
            self.params, self.opt_state, metrics = self._vstep(
                self.params, self.opt_state, batch, self.lr)
            last = metrics
        return float(jnp.mean(last["loss"])) if last else float("nan")

    # ----------------------------------------------------------------- eval
    def _stacked_split(self, split: str, batch: int = 64) -> dict:
        """Client-stacked eval data for one split, shaped
        (N, n_batches, batch, ...): every client padded to the largest
        client rounded up to the microbatch size, pad rows label=-1
        (masked). Eval data is static, so the device-resident stack is
        built once per split."""
        if split not in self._eval_splits:
            n_max = max(len(c[split][1]) for c in self.data)
            n_to = -(-n_max // batch) * batch
            Xs, ys = [], []
            for c in self.data:
                X, y = pad_eval_split(*c[split], n_to)
                Xs.append(X.reshape((n_to // batch, batch) + X.shape[1:]))
                ys.append(y.reshape((n_to // batch, batch) + y.shape[1:]))
            self._eval_splits[split] = make_batch(
                self.cfg, np.stack(Xs), np.stack(ys))
        return self._eval_splits[split]

    def client_scores(self, split: str = "val") -> np.ndarray:
        """Per-client masked accuracy — ONE vmapped device program over
        the client axis per split (was a per-client, per-batch host loop:
        O(N * ceil(n/64)) dispatches per round)."""
        scores = self._veval(self.params, self._stacked_split(split))
        return np.asarray(scores, np.float32)

    def mean_accuracy(self, split: str = "test") -> float:
        """Paper Eq. 3: average of per-client accuracy."""
        return float(self.client_scores(split).mean())

    # ---------------------------------------------------------------- round
    def round(self, r: int, key) -> RoundLog:
        train_loss = self.local_train()
        val = self.client_scores("val")

        if self.aggregation == "none":
            log = RoundLog(r, float(val.mean()), np.zeros(self.n, np.int64),
                           np.array([]), [], train_loss)
            self.history.append(log)
            return log

        if self.aggregation == "fedavg":
            assignments = np.zeros(self.n, np.int64)
            centers = np.array([int(np.argmax(val))])
            events = []
            k = 1
        else:
            # --- BSO-SL: distribution upload -> k-means -> brain storm ---
            # --- the coordinator phase is 3 device programs, not O(N·T):
            # stats (one fused pass), k-means (one jit'd Lloyd loop),
            # and the vmapped eval that produced `val` above
            feats = swarm_distribution_matrix(self.params, self.n,
                                              use_pallas=self.use_pallas)
            k = self.swarm.n_clusters
            _, assign0 = self._kmeans(key, feats, k=k,
                                      iters=self.swarm.kmeans_iters,
                                      use_pallas=self.use_pallas)
            plan = brain_storm(self.np_rng, np.asarray(assign0), val, k,
                               self.swarm.p1, self.swarm.p2)
            assignments, centers, events = plan.assignments, plan.centers, plan.events

        self.params = self._agg(self.params, jnp.asarray(assignments),
                                jnp.asarray(self.n_samples), k=k)
        if self.reset_opt_each_round:
            # optional: re-init optimizer moments after redistribution
            # (paper is silent; measured ablation in benchmarks)
            self.opt_state = jax.vmap(self.opt.init)(self.params)
        log = RoundLog(r, float(val.mean()), np.asarray(assignments),
                       np.asarray(centers), events, train_loss)
        self.history.append(log)
        return log

    def fit(self, key, rounds: Optional[int] = None, verbose: bool = False):
        rounds = rounds or self.swarm.rounds
        for r in range(rounds):
            key, sub = jax.random.split(key)
            log = self.round(r, sub)
            if verbose:
                print(f"[{self.aggregation}] round {r:3d} "
                      f"val_acc={log.mean_val_acc:.4f} loss={log.train_loss:.4f} "
                      + ("; ".join(log.events) if log.events else ""))
        return self.history
