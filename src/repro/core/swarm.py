"""Swarm orchestration (sim regime): N clients as a stacked pytree.

One :class:`SwarmTrainer` runs all four methods of the paper's Table II
via ``aggregation`` mode:

  "bso"     — the full BSO-SL round (§III): local training → distribution
              upload → k-means clustering → brain-storm aggregation.
  "fedavg"  — global FedAvg every round (the federated baseline).
  "none"    — local training only (the isolation baseline).

(The centralized baseline pools data and is in baselines.py.)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, OptimizerConfig, SwarmConfig
from repro.core.aggregation import cluster_fedavg
from repro.core.bso import brain_storm
from repro.core.diststats import swarm_distribution_matrix
from repro.core.kmeans import kmeans
from repro.models.model import Model
from repro.optim.optimizers import make_optimizer
from repro.train.steps import make_eval_step, make_train_step
from repro.utils.tree import tree_index


def make_batch(cfg: ModelConfig, X, y):
    if cfg.family == "cnn":
        return {"images": jnp.asarray(X), "labels": jnp.asarray(y)}
    return {"tokens": jnp.asarray(X), "labels": jnp.asarray(y)}


def _sample_batch(rng, X, y, batch):
    idx = rng.integers(0, len(y), size=batch)
    return X[idx], y[idx]


def eval_client(eval_fn, cfg, params, X, y, batch: int = 64) -> float:
    """Masked fixed-shape evaluation (pads with label=-1)."""
    n = len(y)
    correct, total = 0.0, 0
    for s in range(0, n, batch):
        xb, yb = X[s:s + batch], y[s:s + batch]
        pad = batch - len(yb)
        if pad:
            xb = np.concatenate([xb, np.zeros((pad,) + xb.shape[1:], xb.dtype)])
            yb = np.concatenate([yb, -np.ones((pad,) + yb.shape[1:], yb.dtype)])
        m = eval_fn(params, make_batch(cfg, xb, yb))
        k = len(y[s:s + batch])
        correct += float(m["acc"]) * k
        total += k
    return correct / max(total, 1)


@dataclass
class RoundLog:
    round: int
    mean_val_acc: float
    assignments: np.ndarray
    centers: np.ndarray
    events: List[str]
    train_loss: float


class SwarmTrainer:
    def __init__(self, model: Model, clients_data: List[dict],
                 swarm: SwarmConfig, opt_cfg: OptimizerConfig,
                 key, *, batch_size: int = 16, aggregation: str = "bso",
                 lr: Optional[float] = None, reset_opt_each_round: bool = False):
        assert aggregation in ("bso", "fedavg", "none")
        self.reset_opt_each_round = reset_opt_each_round
        self.model = model
        self.cfg = model.cfg
        self.data = clients_data
        self.swarm = swarm
        self.n = len(clients_data)
        self.batch_size = batch_size
        self.aggregation = aggregation
        self.lr = lr if lr is not None else opt_cfg.lr
        self.opt = make_optimizer(opt_cfg)

        keys = jax.random.split(key, self.n)
        self.params = jax.vmap(model.init)(keys)
        self.opt_state = jax.vmap(self.opt.init)(self.params)
        step = make_train_step(model, self.opt)
        self._vstep = jax.jit(jax.vmap(step, in_axes=(0, 0, 0, None)))
        self._eval = jax.jit(make_eval_step(model))
        self._agg = jax.jit(cluster_fedavg, static_argnames=("k",))
        self.np_rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))
        self.n_samples = np.array([c["n_train"] for c in clients_data], np.float32)
        self.history: List[RoundLog] = []

    # ---------------------------------------------------------------- local
    def _local_steps(self) -> int:
        if self.swarm.local_steps is not None:
            return self.swarm.local_steps
        steps_per_epoch = int(np.ceil(self.n_samples.mean() / self.batch_size))
        return max(1, self.swarm.local_epochs * steps_per_epoch)

    def local_train(self):
        last = None
        for _ in range(self._local_steps()):
            xs, ys = [], []
            for c in self.data:
                X, y = c["train"]
                xb, yb = _sample_batch(self.np_rng, X, y, self.batch_size)
                xs.append(xb)
                ys.append(yb)
            batch = make_batch(self.cfg, np.stack(xs), np.stack(ys))
            self.params, self.opt_state, metrics = self._vstep(
                self.params, self.opt_state, batch, self.lr)
            last = metrics
        return float(jnp.mean(last["loss"])) if last else float("nan")

    # ----------------------------------------------------------------- eval
    def client_scores(self, split: str = "val") -> np.ndarray:
        scores = []
        for i, c in enumerate(self.data):
            X, y = c[split]
            p = tree_index(self.params, i)
            scores.append(eval_client(self._eval, self.cfg, p, X, y))
        return np.asarray(scores, np.float32)

    def mean_accuracy(self, split: str = "test") -> float:
        """Paper Eq. 3: average of per-client accuracy."""
        return float(self.client_scores(split).mean())

    # ---------------------------------------------------------------- round
    def round(self, r: int, key) -> RoundLog:
        train_loss = self.local_train()
        val = self.client_scores("val")

        if self.aggregation == "none":
            log = RoundLog(r, float(val.mean()), np.zeros(self.n, np.int64),
                           np.array([]), [], train_loss)
            self.history.append(log)
            return log

        if self.aggregation == "fedavg":
            assignments = np.zeros(self.n, np.int64)
            centers = np.array([int(np.argmax(val))])
            events = []
            k = 1
        else:
            # --- BSO-SL: distribution upload -> k-means -> brain storm ---
            feats = swarm_distribution_matrix(self.params, self.n)
            k = self.swarm.n_clusters
            _, assign0 = kmeans(key, feats, k, self.swarm.kmeans_iters)
            plan = brain_storm(self.np_rng, np.asarray(assign0), val, k,
                               self.swarm.p1, self.swarm.p2)
            assignments, centers, events = plan.assignments, plan.centers, plan.events

        self.params = self._agg(self.params, jnp.asarray(assignments),
                                jnp.asarray(self.n_samples), k=k)
        if self.reset_opt_each_round:
            # optional: re-init optimizer moments after redistribution
            # (paper is silent; measured ablation in benchmarks)
            self.opt_state = jax.vmap(self.opt.init)(self.params)
        log = RoundLog(r, float(val.mean()), np.asarray(assignments),
                       np.asarray(centers), events, train_loss)
        self.history.append(log)
        return log

    def fit(self, key, rounds: Optional[int] = None, verbose: bool = False):
        rounds = rounds or self.swarm.rounds
        for r in range(rounds):
            key, sub = jax.random.split(key)
            log = self.round(r, sub)
            if verbose:
                print(f"[{self.aggregation}] round {r:3d} "
                      f"val_acc={log.mean_val_acc:.4f} loss={log.train_loss:.4f} "
                      + ("; ".join(log.events) if log.events else ""))
        return self.history
