"""Pytree utilities shared across the framework.

These are deliberately dependency-free (no optax / chex in this
environment); every optimizer and the swarm aggregation layer build on
them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(tree, s):
    return jax.tree.map(lambda x: x * s, tree)


def tree_weighted_sum(trees, weights):
    """sum_i w_i * tree_i — the FedAvg primitive (paper Eq. 2).

    ``trees`` is a list of pytrees with identical structure; ``weights``
    is a 1-D array-like of the same length.
    """
    if len(trees) == 0:
        raise ValueError("tree_weighted_sum needs at least one tree")
    weights = jnp.asarray(weights)

    def _combine(*leaves):
        acc = leaves[0] * weights[0]
        for i in range(1, len(leaves)):
            acc = acc + leaves[i] * weights[i]
        return acc

    return jax.tree.map(_combine, *trees)


def tree_stack(trees):
    """Stack a list of identical-structure pytrees along a new leading
    (client) axis — the sim-regime swarm representation."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree, n):
    """Inverse of tree_stack."""
    return [jax.tree.map(lambda x, i=i: x[i], tree) for i in range(n)]


def tree_index(tree, i):
    return jax.tree.map(lambda x: x[i], tree)


def tree_global_norm(tree):
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def tree_cast(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


def tree_num_params(tree):
    return sum(x.size for x in jax.tree.leaves(tree))


def tree_size_bytes(tree):
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_paths_and_leaves(tree):
    """List of ("a/b/c", leaf) pairs with stable ordering."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        out.append(("/".join(_key_str(k) for k in path), leaf))
    return out


def _key_str(k):
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    if isinstance(k, jax.tree_util.GetAttrKey):
        return str(k.name)
    return str(k)
