from repro.utils.tree import (  # noqa: F401
    tree_add,
    tree_cast,
    tree_global_norm,
    tree_num_params,
    tree_scale,
    tree_size_bytes,
    tree_weighted_sum,
    tree_zeros_like,
)
