"""npz + JSON-manifest checkpointing (orbax is not available offline).

Leaves are stored under their tree paths; restore is into an example
tree (so lists/dicts round-trip without pickling treedefs). Works for
single models and client-stacked swarm pytrees alike.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import numpy as np

from repro.utils.tree import tree_paths_and_leaves


def save_checkpoint(path, tree, *, step: int = 0, extra: dict = None):
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    pairs = tree_paths_and_leaves(tree)
    arrays = {p: np.asarray(l) for p, l in pairs}
    np.savez(path.with_suffix(".npz"), **arrays)
    manifest = {
        "step": step,
        "extra": extra or {},
        "leaves": {p: {"shape": list(a.shape), "dtype": str(a.dtype)}
                   for p, a in arrays.items()},
    }
    path.with_suffix(".json").write_text(json.dumps(manifest, indent=1))


def restore_into(example_tree, path):
    """Returns (tree, step). ``example_tree`` supplies the structure."""
    path = Path(path)
    data = np.load(path.with_suffix(".npz"))
    manifest = json.loads(path.with_suffix(".json").read_text())
    flat, treedef = jax.tree_util.tree_flatten_with_path(example_tree)
    leaves = []
    for kpath, leaf in flat:
        key = "/".join(_k(k) for k in kpath)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf '{key}'")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for '{key}': "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["step"]


def _k(k):
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    return str(k)
