from repro.checkpoint.ckpt import restore_into, save_checkpoint  # noqa: F401
