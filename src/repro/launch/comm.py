"""Communication accounting for the swarm — the §I/§III.B ledger.

The paper's scalability claim is a *traffic* claim: BSO-SL's
coordinator sees only O(#tensors) distribution summaries per client
while the model exchange stays peer-to-peer inside clusters. This
module turns that claim into measured numbers for a compiled fleet
round:

* :func:`collective_bytes` — census of the cross-device collectives in
  optimized HLO (per-device bytes per round). In the fleet regime the
  Eq. 2 ``cluster_fedavg`` segment-sum is what XLA partitions into
  all-reduce/all-gather traffic over the ``pod`` (client) axis, so
  this is the measured "aggregation traffic" of the round program.
* :func:`fleet_round_comm` — the full per-round ledger of one compiled
  fleet round step: the host-facing stat upload / cluster feedback
  (tiny, O(clients)) versus the on-mesh aggregation traffic (measured
  from the HLO, bounded analytically), plus the blockchain-SL and
  FedAvg baselines the paper compares against.

Deliberately side-effect free (no XLA_FLAGS mutation at import — cf.
``repro.launch.dryrun``, which historically owned the HLO parser and
now imports it from here) so the fleet driver and benchmarks can use
it without touching backend state.
"""
from __future__ import annotations

import re

from repro.core.diststats import full_params_bytes, upload_bytes

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "pred": 1,
                "s8": 1, "u8": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shard bytes of every collective op in optimized HLO.
    Returns {op_name: bytes, ..., "total": bytes} (per device)."""
    out = {c: 0 for c in _COLLECTIVES}
    n_ops = {c: 0 for c in _COLLECTIVES}
    # e.g.:  %all-reduce.5 = f32[2048,512]{1,0} all-reduce(...)
    pat = re.compile(
        r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+(" + "|".join(_COLLECTIVES) + r")\(")
    # tuple-result collectives:  = (f32[8]{0}, f32[8]{0}) all-to-all(
    tup = re.compile(
        r"=\s*\(([^)]*)\)\s+(" + "|".join(_COLLECTIVES) + r")\(")
    for line in hlo_text.splitlines():
        m = pat.search(line)
        if m:
            dt, dims, op = m.group(1), m.group(2), m.group(3)
            size = _DTYPE_BYTES.get(dt, 4)
            for d in dims.split(","):
                if d:
                    size *= int(d)
            out[op] += size
            n_ops[op] += 1
            continue
        m = tup.search(line)
        if m:
            parts, op = m.group(1), m.group(2)
            for shp in re.finditer(r"([a-z0-9]+)\[([0-9,]*)\]", parts):
                dt, dims = shp.group(1), shp.group(2)
                size = _DTYPE_BYTES.get(dt, 4)
                for d in dims.split(","):
                    if d:
                        size *= int(d)
                out[op] += size
            n_ops[op] += 1
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    out["op_counts"] = n_ops
    return out


def fleet_round_comm(compiled, params_abs, n_clients: int,
                     batch_bytes: int = 0) -> dict:
    """Per-round communication ledger of ONE compiled fleet round step.

    ``compiled`` is the executable from ``fleet_setup(...).jit_fn
    .lower(...).compile()``; ``params_abs`` the (un-stacked) abstract
    single-client param pytree; ``batch_bytes`` optionally records the
    per-round data upload (client-local minibatches entering the mesh —
    not model traffic, listed separately for honesty).

    Host-facing traffic (the coordinator round-trip, all O(clients)):

    * ``stat_upload_bytes``    — the (N, 2*#tensors) matrix pulled to
      host each round (paper §III.B: the ONLY model-derived upload),
    * ``val_upload_bytes``     — the (N,) val scores the BSA ranks,
    * ``cluster_feedback_bytes`` — the (N,) int32 next-round clusters
      pushed back (plus the (N,) float32 Eq. 2 weights, constant).

    On-mesh traffic (the Eq. 2 exchange — stays client-to-client):

    * ``eq2_collective_bytes`` — measured per-device collective bytes
      parsed from the compiled round's optimized HLO
      (:func:`collective_bytes`; includes the op census),
    * ``eq2_p2p_bound_bytes``  — the analytic 2·N·P·itemsize
      intra-cluster exchange bound used by the §I comparison,
    * ``fedavg_bytes`` / ``blockchain_bytes`` — the server (2·N·P) and
      all-broadcast (N·(N−1)·P) baselines for the same model.

    ``cost_analysis`` carries XLA's own flops / bytes-accessed estimate
    when the backend provides one.
    """
    up = upload_bytes(params_abs)
    full = full_params_bytes(params_abs)
    try:
        hlo = compiled.as_text()
    except Exception:  # backend without HLO text dumps
        hlo = ""
    cost = {}
    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        cost = {k: float(ca[k]) for k in ("flops", "bytes accessed")
                if k in ca}
    except Exception:
        pass
    return {
        "n_clients": n_clients,
        "stat_upload_bytes": n_clients * up,
        "val_upload_bytes": n_clients * 4,
        "cluster_feedback_bytes": n_clients * (4 + 4),
        "batch_upload_bytes": int(batch_bytes),
        "eq2_collective_bytes": collective_bytes(hlo),
        "eq2_p2p_bound_bytes": 2 * n_clients * full,
        "fedavg_bytes": 2 * n_clients * full,
        "blockchain_bytes": n_clients * (n_clients - 1) * full,
        "full_params_bytes": full,
        "coord_reduction_x": full / max(up, 1),
        "cost_analysis": cost,
    }


def hier_host_bytes(params_abs, n_clients: int, n_pods: int,
                    k_local: int) -> dict:
    """The analytical host-facing ledger of ONE two-tier round, and the
    flat O(clients) round it replaces — pure arithmetic on the abstract
    params, no compiled program required (the extrapolation half of the
    ``BENCH_hier.json`` scaling claim; :func:`hier_round_comm` attaches
    the same numbers to a measured round).

    Upload (device -> host), per round:

    * flat: every client sends its (2*#tensors,) stat row plus a f32
      val score — ``N * (up + 4)``.
    * hier: only the ``S = n_pods * k_local`` pod-cluster summaries
      cross — per row the centroid (``up`` bytes) plus three f32
      scalars (count, weight sum, val sum) — ``S * (up + 12)`` (plus
      two O(1) scalars, mean val + loss, counted separately).

    Feedback (host -> device), per round:

    * flat: the (N,) int32 cluster decision + (N,) f32 Eq. 2 weights.
    * hier: the (S,) int32 pod-cluster -> global-cluster map ``g`` plus
      the O(1) ``use_composed`` flag and the 8-byte k-means key — the
      (N,) fallback/feedback arrays live on-device and never move.
    """
    up = upload_bytes(params_abs)
    S = n_pods * k_local
    return {
        "n_clients": n_clients,
        "n_pods": n_pods,
        "k_local": k_local,
        "summary_rows": S,
        "flat_upload_bytes": n_clients * (up + 4),
        "flat_feedback_bytes": n_clients * (4 + 4),
        "summary_upload_bytes": S * (up + 12),
        "scalar_upload_bytes": 8,
        "hier_feedback_bytes": S * 4 + 9,
        "hier_reduction_x": (n_clients * (up + 4))
        / max(S * (up + 12), 1),
    }


def hier_round_comm(compiled, params_abs, n_clients: int, *, n_pods: int,
                    k_local: int, batch_bytes: int = 0) -> dict:
    """Per-round ledger of ONE compiled HIERARCHICAL fleet round step —
    the two-tier counterpart of :func:`fleet_round_comm`.

    Host-facing traffic is the :func:`hier_host_bytes` arithmetic (the
    O(pods) summaries up, the (S,) map ``g`` down); the on-mesh Eq. 2
    exchange, the §I baselines and XLA's cost analysis are measured the
    same way as the flat ledger. The pod-local k-means adds NO host
    traffic at all — it runs inside the round program; its cost shows
    up only in ``cost_analysis``/``eq2_collective_bytes``.
    """
    full = full_params_bytes(params_abs)
    try:
        hlo = compiled.as_text()
    except Exception:  # backend without HLO text dumps
        hlo = ""
    cost = {}
    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        cost = {k: float(ca[k]) for k in ("flops", "bytes accessed")
                if k in ca}
    except Exception:
        pass
    out = hier_host_bytes(params_abs, n_clients, n_pods, k_local)
    out.update({
        "batch_upload_bytes": int(batch_bytes),
        "eq2_collective_bytes": collective_bytes(hlo),
        "eq2_p2p_bound_bytes": 2 * n_clients * full,
        "fedavg_bytes": 2 * n_clients * full,
        "blockchain_bytes": n_clients * (n_clients - 1) * full,
        "full_params_bytes": full,
        "cost_analysis": cost,
    })
    return out


def hier_scaling_table(params_abs, *, pod_size: int, k_local: int,
                       n_clients=(10_000, 100_000, 1_000_000)) -> list:
    """Analytical extrapolation of the per-round host-facing bytes to
    swarm sizes no host could serve flat — one :func:`hier_host_bytes`
    row per N at fixed pod size (so pods grow with N and the hier curve
    stays O(N / pod_size) while flat is O(N)). This is the ledger the
    measured small-N slope in ``benchmarks/hier_bench.py`` is checked
    against."""
    rows = []
    for n in n_clients:
        n = int(n)
        pods = -(-n // pod_size)
        row = hier_host_bytes(params_abs, n, pods, k_local)
        row["pod_size"] = pod_size
        rows.append(row)
    return rows
