"""End-to-end training driver.

Two modes:
  * single   — train one LM on synthetic non-IID token data (the
               "~100M model for a few hundred steps" driver: use
               --preset 100m).
  * swarm    — the full BSO-SL protocol over N simulated clients with
               any --arch (LM or CNN families).

Examples:
  PYTHONPATH=src python -m repro.launch.train --mode single --preset tiny --steps 50
  PYTHONPATH=src python -m repro.launch.train --mode single --preset 100m --steps 300
  PYTHONPATH=src python -m repro.launch.train --mode swarm --arch squeezenet-dr --rounds 5
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.configs.base import ModelConfig, OptimizerConfig, SwarmConfig
from repro.core.swarm import SwarmTrainer
from repro.data.dr import make_dr_swarm_data, TABLE_I
from repro.data.tokens import make_lm_batches, make_token_swarm_data
from repro.models import build_model
from repro.optim.optimizers import make_optimizer
from repro.optim.schedules import make_schedule
from repro.train.steps import make_train_step

PRESETS = {
    # ~1M params — smoke
    "tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                 d_ff=512, vocab_size=512),
    # ~26M params — CI-scale e2e
    "26m": dict(n_layers=6, d_model=512, n_heads=8, n_kv_heads=4,
                d_ff=2048, vocab_size=2048),
    # ~104M params — the paper-scale end-to-end driver
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=3072, vocab_size=8192),
}


def preset_config(name: str) -> ModelConfig:
    return ModelConfig(arch_id=f"lm-{name}", family="dense", act="swiglu",
                       norm="rmsnorm", dtype="float32", param_dtype="float32",
                       scan_layers=False, **PRESETS[name])


def run_single(args):
    cfg = preset_config(args.preset)
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    n = model.param_count(params)
    print(f"[train] arch={cfg.arch_id} params={n:,}")

    opt = make_optimizer(OptimizerConfig(name="adamw", lr=args.lr))
    opt_state = opt.init(params)
    sched = make_schedule("cosine", args.lr, warmup=max(10, args.steps // 20),
                          total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt))

    t0 = time.time()
    it = make_lm_batches(cfg.vocab_size, args.batch, args.seq, args.steps,
                         client=0, seed=args.seed)
    for i, batch in enumerate(it):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, b,
                                             jnp.asarray(sched(i)))
        if i % max(1, args.steps // 20) == 0 or i == args.steps - 1:
            dt = time.time() - t0
            tok_s = (i + 1) * args.batch * args.seq / dt
            print(f"step {i:5d} loss={float(metrics['ce']):.4f} "
                  f"acc={float(metrics['acc']):.4f} tok/s={tok_s:,.0f}")
    if args.ckpt:
        save_checkpoint(args.ckpt, params, step=args.steps)
        print(f"checkpoint saved to {args.ckpt}.npz")
    return float(metrics["ce"])


def run_swarm(args):
    cfg = get_config(args.arch)
    if cfg.family == "cnn":
        clients = make_dr_swarm_data(image_size=args.image_size, seed=args.seed,
                                     table=_scaled_table(args.data_scale))
    else:
        cfg = cfg.smoke()
        clients = make_token_swarm_data(args.clients, cfg.vocab_size,
                                        n_seqs=32, seq_len=64, seed=args.seed)
    model = build_model(cfg)
    swarm = SwarmConfig(n_clients=len(clients), n_clusters=args.clusters,
                        rounds=args.rounds, local_steps=args.local_steps)
    tr = SwarmTrainer(model, clients, swarm,
                      OptimizerConfig(name="adam", lr=args.lr),
                      jax.random.PRNGKey(args.seed),
                      batch_size=args.batch, aggregation="bso")
    tr.fit(jax.random.PRNGKey(args.seed + 1), verbose=True)
    acc = tr.mean_accuracy("test")
    print(f"[swarm] final mean test accuracy (Eq.3): {acc:.4f}")
    return acc


def _scaled_table(scale: int):
    if scale <= 1:
        return TABLE_I
    t = np.maximum(TABLE_I // scale, (TABLE_I > 0).astype(np.int64) * 2)
    return t


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="single", choices=["single", "swarm"])
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--arch", default="squeezenet-dr")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--clients", type=int, default=14)
    ap.add_argument("--clusters", type=int, default=3)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--image-size", type=int, default=16)
    ap.add_argument("--data-scale", type=int, default=8,
                    help="divide Table I counts by this for CPU runs")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()
    if args.mode == "single":
        run_single(args)
    else:
        run_swarm(args)


if __name__ == "__main__":
    main()
