"""End-to-end multi-round BSO-SL on the pod mesh — the fleet driver.

This is the first surface where the WHOLE paper protocol runs in the
fleet regime rather than as a one-step lowering artifact: the round
program (``engine.make_fleet_round`` via ``swarm_fleet.fleet_setup``)
is compiled ONCE on the mesh, and the driver then closes the paper's
coordinator loop for R rounds:

  1. execute the fused fleet step — Eq. 2 on the incoming cluster
     decision, local SGD on the uploaded round batch, in-program val
     eval and distribution-stat upload (one executable, donated
     params/opt buffers, zero retraces),
  2. pull ONLY the tiny :class:`~repro.core.engine.FleetRoundOut`
     (the (N, 2·#tensors) stat matrix + (N,) val scores) to host,
  3. run the host-side coordinator — k-means on the stats plus the
     numpy ``brain_storm`` oracle, the paper's neighbour-assignment
     server (§III.B/C) — and feed the resulting ``clusters`` into the
     next round's donated buffers.

Because the round program aggregates FIRST (see
:func:`repro.core.engine.make_fleet_round`), R driver rounds execute
exactly the sim engine's protocol sequence (train → eval → stats →
coordinator → Eq. 2, R times) with the final Eq. 2 left pending on the
mesh. Parity with ``engine.run_rounds`` is therefore *statistical*,
not bitwise: the fleet samples batches host-side and the coordinator
consumes different RNG streams (numpy ``brain_storm`` vs the engine's
``brain_storm_jax``) — the same documented caveat as the existing
numpy-oracle parity (``tests/test_engine.py``). The per-round
trajectory property is pinned in ``tests/test_fleet.py``.

Unit scale (the 8-device CPU stand-in, small CNN clients) runs the
identical driver code: ``make_unit_fleet`` + :func:`run_fleet` is both
the tier-1 smoke and the traffic benchmark behind ``BENCH_fleet.json``
(``python -m benchmarks.comm_scaling --fleet``).

CLI::

    PYTHONPATH=src python -m repro.launch.fleet_driver --rounds 3
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Any, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import OptimizerConfig, asdict
from repro.checkpoint import save_checkpoint
from repro.core.aggregation import cluster_fedavg, singleton_assignments
from repro.core.bso import brain_storm
from repro.core.engine import make_batch, make_client_eval, stack_eval_split
from repro.core.kmeans import kmeans
from repro.data.dr import bucket_clients, make_dr_swarm_data, scale_table
from repro.launch.comm import fleet_round_comm
from repro.launch.mesh import make_fleet_mesh
from repro.launch.swarm_fleet import fleet_setup, force_host_device_count
from repro.models import build_model
from repro.optim.optimizers import make_optimizer
from repro.sharding import use_sharding

# ------------------------------------------------------- host coordinator


_jit_kmeans = jax.jit(kmeans, static_argnames=("k", "iters"))


def host_coordinator(stats, val_acc, *, k: int, p1: float, p2: float,
                     kmeans_iters: int = 20, seed: int = 0,
                     round_idx: int = 0):
    """The paper's neighbour-assignment server, as a pure host function.

    Deterministic in ``(stats, val_acc, seed, round_idx)``: the k-means
    key is ``fold_in(PRNGKey(seed), round_idx)`` and the brain-storm
    stream is ``default_rng([seed, round_idx])``, so replaying a round's
    uploaded stats reproduces its cluster decision bit-for-bit (the
    determinism contract ``tests/test_fleet.py`` pins). Reuses the sim
    engine's k-means and the numpy ``brain_storm`` oracle — O(clients)
    work on a (N, 2·#tensors) matrix, negligible next to the round step.

    Returns ``(assignments, centers, events)`` — the (N,) int32 cluster
    decision to feed into the NEXT round's Eq. 2, the (k,) center client
    ids, and the human-readable BSA event log.
    """
    key = jax.random.fold_in(jax.random.PRNGKey(seed), round_idx)
    _, a0 = _jit_kmeans(key, jnp.asarray(stats, jnp.float32), k=k,
                        iters=kmeans_iters)
    rng = np.random.default_rng([seed, round_idx])
    plan = brain_storm(rng, np.asarray(a0), np.asarray(val_acc), k, p1, p2)
    return (plan.assignments.astype(np.int32),
            plan.centers.astype(np.int32), plan.events)


# ------------------------------------------------------------- the driver


@dataclass
class FleetRoundLog:
    """One driver round: the protocol artifacts pulled to host."""
    round: int
    mean_val_acc: float                # Eq. 3 over the val split
    val_acc: np.ndarray                # (N,)
    train_loss: float
    stats: np.ndarray                  # (N, 2*#tensors) §III.B upload
    assignments: np.ndarray            # (N,) decision FROM this round's
    #                                    stats (applied next round)
    centers: np.ndarray                # (k,) BSA center client ids
    applied_clusters: np.ndarray       # (N,) decision fed INTO this round
    events: List[str]
    wall_s: float
    coord_s: float


@dataclass
class FleetRunResult:
    history: List[FleetRoundLog]
    n_compiles: int                    # always 1 — the acceptance property
    comm: dict                         # per-round ledger (launch.comm)
    params: Any                        # final client-stacked params (on mesh)
    compile_s: float = 0.0
    meta: dict = field(default_factory=dict)

    @property
    def mean_val_accs(self):
        return [r.mean_val_acc for r in self.history]


def make_unit_fleet(n_clients: int = 8, *, arch: str = "squeezenet-dr",
                    image_size: int = 16, data_scale: int = 16,
                    seed: int = 0, lr: float = 2e-3):
    """Unit-scale fleet: the first ``n_clients`` Table-I clinics, one
    per pod slot of :func:`make_fleet_mesh` (one clinic per device on
    the 8-device CPU stand-in). Returns ``(model, opt, mesh,
    clients_data)`` — the arguments :func:`run_fleet` wants."""
    table = scale_table(data_scale)[:, :n_clients]
    clients = make_dr_swarm_data(image_size=image_size, seed=seed,
                                 table=table)
    model = build_model(get_config(arch))
    opt = make_optimizer(OptimizerConfig(name="adam", lr=lr))
    return model, opt, make_fleet_mesh(len(clients)), clients


def _sample_round_batch(model_cfg, clients_data, n_rows: int, seed: int,
                        round_idx: int):
    """Host-side per-round batch upload: every client draws ``n_rows``
    uniform-with-replacement rows from its own train split — the same
    distribution as the engine's on-device per-step sampler, stacked as
    the (N, n_rows, ...) round batch the fleet step slices per step."""
    Xs, ys = [], []
    for i, c in enumerate(clients_data):
        rng = np.random.default_rng([seed, round_idx, i])
        X, y = c["train"]
        idx = rng.integers(0, len(y), size=n_rows)
        Xs.append(X[idx])
        ys.append(y[idx])
    return make_batch(model_cfg, np.stack(Xs), np.stack(ys))


def export_fleet_checkpoint(path, model, sparams, clusters, weights, *,
                            round_idx: int, n_clusters: int,
                            mean_val_acc: float = 0.0):
    """Serialize the swarm state for ``repro.serve``.

    Applies the round's pending Eq. 2 (the aggregation the NEXT round
    would fold in) so the checkpoint holds each client's cluster
    aggregate, then saves the client-stacked tree with a manifest
    ``extra`` sufficient to rebuild the model serve-side with no
    training code: the full ``ModelConfig`` asdict, client count,
    |D_h| weights and the cluster decision.
    """
    agg = cluster_fedavg(sparams, jnp.asarray(clusters),
                         jnp.asarray(weights, jnp.float32),
                         k=len(np.asarray(clusters)))
    save_checkpoint(path, agg, step=round_idx + 1, extra={
        "model_config": asdict(model.cfg),
        "n_clients": int(len(np.asarray(clusters))),
        "client_weights": np.asarray(weights, np.float32).tolist(),
        "assignments": np.asarray(clusters, np.int32).tolist(),
        "n_clusters": int(n_clusters),
        "mean_val_acc": float(mean_val_acc),
    })


def run_fleet(model, opt, mesh, clients_data, *, rounds: int,
              local_steps: int = 4, batch_size: int = 8, lr: float = 2e-3,
              n_clusters: int = 3, p1: float = 0.9, p2: float = 0.8,
              kmeans_iters: int = 20, seed: int = 0,
              use_pallas_stats: bool = False, eval_batch: int = 64,
              eval_buckets: int = 0, bucket_strategy: str = "pow2",
              ckpt_path=None, ckpt_every: int = 0,
              verbose: bool = False) -> FleetRunResult:
    """Drive ``rounds`` full BSO-SL rounds on ``mesh`` with exactly ONE
    compiled fleet-round executable.

    The round step is lowered and compiled once (AOT) with donated
    params/opt buffers; every round re-invokes the same executable with
    the freshly uploaded batch and the previous round's host cluster
    decision. Round 0 feeds ``singleton_assignments`` (Eq. 2 is the
    bitwise identity), so the executed protocol sequence matches the
    sim engine's round for round — see the module docstring.

    ``eval_buckets > 0`` switches val scoring onto the bucketed ragged
    layout: clients are grouped into size buckets
    (:func:`repro.data.dr.bucket_clients` on the val-split sizes), each
    bucket's eval stack is padded only to its own ceiling, and the
    driver compiles ONE fixed-shape eval program per bucket signature
    (round program built ``with_loss`` — no rectangular val stack rides
    the mesh). The compile budget becomes ``1 + n_buckets`` executables
    total, still zero per-round retraces, and the per-client accuracies
    are identical to the in-program rectangular eval (same
    post-local-phase params, same masked reduction —
    ``tests/test_fleet.py`` pins the parity).
    """
    N = len(clients_data)
    if n_clusters > N:
        raise ValueError(f"n_clusters={n_clusters} > n_clients={N}")
    bucketed = eval_buckets > 0
    program = fleet_setup(model, opt, mesh, k=N, n_local_steps=local_steps,
                          use_pallas_stats=use_pallas_stats,
                          with_eval=not bucketed, with_loss=bucketed,
                          donate=True, spmd="shard_map")
    if bucketed:
        _, _, bsh, lsh, csh, wsh = program.in_shardings
    else:
        _, _, bsh, vsh, lsh, csh, wsh = program.in_shardings
    lr_arr = jax.device_put(jnp.float32(lr), lsh)

    with mesh, use_sharding(mesh, program.rules):
        keys = jax.random.split(jax.random.PRNGKey(seed), N)
        psh, osh = program.in_shardings[0], program.in_shardings[1]
        sparams = jax.jit(lambda ks: jax.vmap(model.init)(ks),
                          out_shardings=psh)(keys)
        sopt = jax.jit(lambda p: jax.vmap(opt.init)(p),
                       out_shardings=osh)(sparams)
        eval_progs = []
        if bucketed:
            # one fixed-shape eval program per bucket: gather the
            # bucket's client params, score its own-ceiling val stack
            rep = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec())
            groups = bucket_clients(
                [len(c["val"][1]) for c in clients_data],
                max_buckets=eval_buckets, strategy=bucket_strategy)
            ev = make_client_eval(model)
            for ids in groups:
                ids_arr = np.asarray(ids)
                val_b = jax.device_put(
                    stack_eval_split(model.cfg,
                                     [clients_data[i] for i in ids],
                                     "val", batch=eval_batch), rep)
                fn = jax.jit(lambda p, v, _ids=ids_arr: ev(
                    jax.tree.map(lambda x: x[_ids], p), v))
                eval_progs.append((ids_arr, val_b, fn))
        else:
            val = jax.device_put(
                stack_eval_split(model.cfg, clients_data, "val",
                                 batch=eval_batch), vsh)
        weights = jax.device_put(
            np.asarray([c["n_train"] for c in clients_data], np.float32),
            wsh)
        clusters = np.asarray(singleton_assignments(N))

        def put_batch(r):
            batch = _sample_round_batch(model.cfg, clients_data,
                                        local_steps * batch_size, seed, r)
            return jax.device_put(batch, bsh)

        # ONE lowering -> ONE executable for every round
        t0 = time.perf_counter()
        batch0 = put_batch(0)
        if bucketed:
            lowered = program.jit_fn.lower(
                sparams, sopt, batch0, lr_arr,
                jax.device_put(clusters, csh), weights)
        else:
            lowered = program.jit_fn.lower(
                sparams, sopt, batch0, val, lr_arr,
                jax.device_put(clusters, csh), weights)
        compiled = lowered.compile()
        compile_s = time.perf_counter() - t0
        batch_bytes = sum(x.size * x.dtype.itemsize
                          for x in jax.tree.leaves(batch0))
        params_abs = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        comm = fleet_round_comm(compiled, params_abs, N,
                                batch_bytes=batch_bytes)

        history = []
        for r in range(rounds):
            t0 = time.perf_counter()
            # round 0 re-uploads the batch the lowering used (sampling is
            # deterministic per (seed, r)) so every round's wall_s covers
            # the same work: sample + upload + round step + stat pull
            batch = put_batch(r)
            applied = clusters
            if bucketed:
                sparams, sopt, stats_dev, loss_dev = compiled(
                    sparams, sopt, batch, lr_arr,
                    jax.device_put(applied, csh), weights)
                stats = np.asarray(stats_dev)
                # per-bucket scoring of the returned post-local-phase
                # params — the same protocol point as the in-program eval
                val_acc = np.zeros(N, np.float32)
                for ids_arr, val_b, fn in eval_progs:
                    val_acc[ids_arr] = np.asarray(fn(sparams, val_b))
                train_loss = float(loss_dev)
            else:
                sparams, sopt, out = compiled(
                    sparams, sopt, batch, val, lr_arr,
                    jax.device_put(applied, csh), weights)
                # the ONLY device->host pull: the tiny FleetRoundOut
                stats = np.asarray(out.stats)
                val_acc = np.asarray(out.val_acc)
                train_loss = float(out.train_loss)
            t1 = time.perf_counter()
            clusters, centers, events = host_coordinator(
                stats, val_acc, k=n_clusters, p1=p1, p2=p2,
                kmeans_iters=kmeans_iters, seed=seed, round_idx=r)
            t2 = time.perf_counter()
            log = FleetRoundLog(
                round=r, mean_val_acc=float(val_acc.mean()),
                val_acc=val_acc, train_loss=train_loss,
                stats=stats, assignments=clusters, centers=centers,
                applied_clusters=applied, events=list(events),
                wall_s=t1 - t0, coord_s=t2 - t1)
            history.append(log)
            if ckpt_path and ckpt_every and (r + 1) % ckpt_every == 0 \
                    and r != rounds - 1:
                export_fleet_checkpoint(
                    f"{ckpt_path}_r{r + 1}", model, sparams, clusters,
                    np.asarray(weights), round_idx=r, n_clusters=n_clusters,
                    mean_val_acc=log.mean_val_acc)
            if verbose:
                print(f"[fleet] round {r}: val_acc={log.mean_val_acc:.3f} "
                      f"loss={log.train_loss:.3f} "
                      f"clusters={np.bincount(clusters, minlength=n_clusters)}"
                      f" events={len(events)} wall={log.wall_s:.2f}s")

    if ckpt_path and history:
        # final export: fold in the pending Eq. 2 (see module docstring)
        export_fleet_checkpoint(
            ckpt_path, model, sparams, history[-1].assignments,
            np.asarray(weights), round_idx=rounds - 1,
            n_clusters=n_clusters, mean_val_acc=history[-1].mean_val_acc)
    meta = dict(n_clients=N, rounds=rounds, local_steps=local_steps,
                batch_size=batch_size, lr=lr, n_clusters=n_clusters, p1=p1,
                p2=p2, seed=seed, mesh_shape=dict(mesh.shape),
                n_devices=mesh.size,
                eval_buckets=len(eval_progs) if bucketed else 0)
    # measured, not asserted: the AOT `compiled` path performs exactly the
    # one .compile() above, and any (future) direct jit_fn dispatches
    # would land in its trace cache — so this catches a regression that
    # reintroduces per-round retracing. Bucketed eval adds exactly one
    # compiled program per bucket signature (their jit caches never grow
    # past 1 — same shapes every round).
    n_compiles = (1 + program.jit_fn._cache_size()
                  + sum(fn._cache_size() for _, _, fn in eval_progs))
    return FleetRunResult(history=history, n_compiles=n_compiles, comm=comm,
                          params=sparams, compile_s=compile_s, meta=meta)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--data-scale", type=int, default=16)
    ap.add_argument("--image-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--devices", type=int, default=8,
                    help="CPU stand-in device count (0 = leave backend "
                         "alone)")
    ap.add_argument("--pallas-stats", action="store_true")
    ap.add_argument("--eval-buckets", type=int, default=0,
                    help="bucket the val eval into at most this many "
                         "size buckets (0 = rectangular in-program eval)")
    ap.add_argument("--ckpt", default=None, metavar="PATH",
                    help="export the final aggregated swarm params "
                         "(npz + manifest) for repro.serve")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="also export every N rounds (PATH_r<N>)")
    args = ap.parse_args()
    if args.devices:
        force_host_device_count(args.devices)
    model, opt, mesh, clients = make_unit_fleet(
        args.clients, image_size=args.image_size,
        data_scale=args.data_scale, seed=args.seed)
    res = run_fleet(model, opt, mesh, clients, rounds=args.rounds,
                    local_steps=args.local_steps,
                    batch_size=args.batch_size, seed=args.seed,
                    use_pallas_stats=args.pallas_stats,
                    eval_buckets=args.eval_buckets,
                    ckpt_path=args.ckpt, ckpt_every=args.ckpt_every,
                    verbose=True)
    if args.ckpt:
        print(f"[fleet] checkpoint -> {args.ckpt}.npz")
    up = res.comm["stat_upload_bytes"]
    coll = res.comm["eq2_collective_bytes"]["total"]
    print(f"[fleet] {res.meta['n_clients']} clients on "
          f"{res.meta['n_devices']} devices, {args.rounds} rounds, "
          f"{res.n_compiles} compile ({res.compile_s:.1f}s); per round: "
          f"stat upload {up} B to host, Eq.2 collectives {coll} B/device")


if __name__ == "__main__":
    main()
