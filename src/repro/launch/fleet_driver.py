"""End-to-end multi-round BSO-SL on the pod mesh — the fleet driver.

This is the first surface where the WHOLE paper protocol runs in the
fleet regime rather than as a one-step lowering artifact: the round
program (``engine.make_fleet_round`` via ``swarm_fleet.fleet_setup``)
is compiled ONCE on the mesh, and the driver then closes the paper's
coordinator loop for R rounds:

  1. execute the fused fleet step — Eq. 2 on the incoming cluster
     decision, local SGD on the uploaded round batch, in-program val
     eval and distribution-stat upload (one executable, donated
     params/opt buffers, zero retraces),
  2. pull ONLY the tiny :class:`~repro.core.engine.FleetRoundOut`
     (the (N, 2·#tensors) stat matrix + (N,) val scores) to host,
  3. run the host-side coordinator — k-means on the stats plus the
     numpy ``brain_storm`` oracle, the paper's neighbour-assignment
     server (§III.B/C) — and feed the resulting ``clusters`` into the
     next round's donated buffers.

Because the round program aggregates FIRST (see
:func:`repro.core.engine.make_fleet_round`), R driver rounds execute
exactly the sim engine's protocol sequence (train → eval → stats →
coordinator → Eq. 2, R times) with the final Eq. 2 left pending on the
mesh. Parity with ``engine.run_rounds`` is therefore *statistical*,
not bitwise: the fleet samples batches host-side and the coordinator
consumes different RNG streams (numpy ``brain_storm`` vs the engine's
``brain_storm_jax``) — the same documented caveat as the existing
numpy-oracle parity (``tests/test_engine.py``). The per-round
trajectory property is pinned in ``tests/test_fleet.py``.

Unit scale (the 8-device CPU stand-in, small CNN clients) runs the
identical driver code: ``make_unit_fleet`` + :func:`run_fleet` is both
the tier-1 smoke and the traffic benchmark behind ``BENCH_fleet.json``
(``python -m benchmarks.comm_scaling --fleet``).

CLI::

    PYTHONPATH=src python -m repro.launch.fleet_driver --rounds 3
"""
from __future__ import annotations

import argparse
import time
import warnings
from dataclasses import dataclass, field
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import OptimizerConfig, asdict
from repro.checkpoint import save_checkpoint
from repro.core.aggregation import (cluster_fedavg, cluster_fedavg_masked,
                                    singleton_assignments)
from repro.core.bso import brain_storm
from repro.core.engine import make_batch, make_client_eval, stack_eval_split
from repro.core.kmeans import kmeans
from repro.data.dr import bucket_clients, make_dr_swarm_data, scale_table
from repro.launch.comm import fleet_round_comm, hier_round_comm
from repro.launch.mesh import make_fleet_mesh
from repro.launch.swarm_fleet import fleet_setup, force_host_device_count
from repro.models import build_model
from repro.optim.optimizers import make_optimizer
from repro.sharding import use_sharding

# ------------------------------------------------------- host coordinator


_jit_kmeans = jax.jit(kmeans, static_argnames=("k", "iters"))


def host_coordinator(stats, val_acc, *, k: int, p1: float, p2: float,
                     kmeans_iters: int = 20, seed: int = 0,
                     round_idx: int = 0):
    """The paper's neighbour-assignment server, as a pure host function.

    Deterministic in ``(stats, val_acc, seed, round_idx)``: the k-means
    key is ``fold_in(PRNGKey(seed), round_idx)`` and the brain-storm
    stream is ``default_rng([seed, round_idx])``, so replaying a round's
    uploaded stats reproduces its cluster decision bit-for-bit (the
    determinism contract ``tests/test_fleet.py`` pins). Reuses the sim
    engine's k-means and the numpy ``brain_storm`` oracle — O(clients)
    work on a (N, 2·#tensors) matrix, negligible next to the round step.

    Returns ``(assignments, centers, events)`` — the (N,) int32 cluster
    decision to feed into the NEXT round's Eq. 2, the (k,) center client
    ids, and the human-readable BSA event log.
    """
    key = jax.random.fold_in(jax.random.PRNGKey(seed), round_idx)
    _, a0 = _jit_kmeans(key, jnp.asarray(stats, jnp.float32), k=k,
                        iters=kmeans_iters)
    rng = np.random.default_rng([seed, round_idx])
    plan = brain_storm(rng, np.asarray(a0), np.asarray(val_acc), k, p1, p2)
    return (plan.assignments.astype(np.int32),
            plan.centers.astype(np.int32), plan.events)


def _hier_val_means(counts, valsums):
    """Per-summary-row mean val accuracy; empty rows (a pod-cluster that
    captured no reporting clients) get -1.0 — inert under the BSA's
    best-score ranking, never a center."""
    counts = np.asarray(counts, np.float32)
    return np.where(counts > 0,
                    np.asarray(valsums, np.float32)
                    / np.maximum(counts, np.float32(1e-9)),
                    np.float32(-1.0)).astype(np.float32)


def host_hier_coordinator(centroids, counts, valsums, *, k: int, p1: float,
                          p2: float, kmeans_iters: int = 20, seed: int = 0,
                          round_idx: int = 0):
    """The two-tier coordinator's global tier — O(pods), not O(clients).

    Mirrors :func:`host_coordinator` (same per-round key/rng streams, so
    a round replays bit-for-bit from its pulled summaries) but consumes
    the ``S = pods * k_local`` pod-cluster summaries of
    :class:`~repro.core.engine.HierRoundOut` instead of per-client rows:
    WEIGHTED k-means over the pod centroids (weights = reporting-member
    counts, so an empty summary row anchors nothing) and the numpy
    ``brain_storm`` over the per-row mean val scores (empty rows -1.0,
    inert). Returns ``(g, centers, events)`` — the (S,) pod-cluster ->
    global-cluster map the round program composes in-program via
    ``g[a_local]``, and the (k,) center *summary-row* ids (not client
    ids — the host never sees clients on this surface).
    """
    key = jax.random.fold_in(jax.random.PRNGKey(seed), round_idx)
    w = jnp.asarray(counts, jnp.float32)
    _, a0 = _jit_kmeans(key, jnp.asarray(centroids, jnp.float32), k=k,
                        iters=kmeans_iters, weights=w)
    rng = np.random.default_rng([seed, round_idx])
    plan = brain_storm(rng, np.asarray(a0), _hier_val_means(counts, valsums),
                       k, p1, p2)
    return (plan.assignments.astype(np.int32),
            plan.centers.astype(np.int32), plan.events)


# -------------------------------------------------------- fault injection


# fault draws get their own host RNG stream: a 4-element seed can never
# collide with the coordinator's [seed, round] or the batch sampler's
# [seed, round, client] streams
_FAULT_STREAM_TAG = (0xFA, 0x17)


@dataclass(frozen=True)
class FleetFaults:
    """Host-side fault-injection regime for :func:`run_fleet`.

    ``drop_rate``      — per-round Bernoulli probability that a client
                         drops: no local phase (masked no-op on device),
                         no report to the coordinator, zero (or decayed)
                         weight in the next Eq. 2.
    ``straggler_rate`` — probability that a *non-dropped* client
                         straggles: it trains this round but its report
                         misses the coordinator deadline (the
                         coordinator falls back to its last-seen stats).
    ``delay_s``        — the straggler-delay model: each straggler's
                         report is late by this many (simulated) wall
                         seconds; logged per round as ``sim_delay_s``,
                         never slept.
    ``stale_decay``    — λ of the staleness-weighted Eq. 2: an absent
                         client keeps weight |D_h|·λ^staleness instead
                         of 0 (λ=0 is the hard participation mask —
                         0^0 == 1 keeps fresh clients at full weight).
    ``quorum``         — coordinator quorum Q: the coordinator only
                         recomputes the cluster decision when ≥ Q
                         clients report this round; below quorum it
                         re-applies the previous decision (round 0's
                         singleton fallback included) and the round is
                         logged ``coordinated=False``.

    All draws are deterministic in ``(seed, round_idx)`` via a dedicated
    ``default_rng`` stream, so a fault schedule replays bit-for-bit —
    the determinism contract ``tests/test_churn.py`` pins.
    """
    drop_rate: float = 0.0
    straggler_rate: float = 0.0
    delay_s: float = 0.0
    stale_decay: float = 0.0
    quorum: int = 0

    @property
    def active(self) -> bool:
        return (self.drop_rate > 0 or self.straggler_rate > 0
                or self.quorum > 0)


def draw_faults(faults: FleetFaults, n_clients: int, seed: int,
                round_idx: int):
    """One round's fault draw: ``(present, straggler)`` bool (N,) arrays.
    Stragglers are drawn among present clients only (a dropped client
    has nothing to be late with)."""
    rng = np.random.default_rng([seed, round_idx, *_FAULT_STREAM_TAG])
    present = rng.random(n_clients) >= faults.drop_rate
    straggler = present & (rng.random(n_clients) < faults.straggler_rate)
    return present, straggler


# ------------------------------------------------------------- the driver


@dataclass
class FleetRoundLog:
    """One driver round: the protocol artifacts pulled to host."""
    round: int
    mean_val_acc: float                # Eq. 3 over the val split
    val_acc: np.ndarray                # (N,)
    train_loss: float
    stats: np.ndarray                  # (N, 2*#tensors) §III.B upload
    assignments: np.ndarray            # (N,) decision FROM this round's
    #                                    stats (applied next round)
    centers: np.ndarray                # (k,) BSA center client ids
    applied_clusters: np.ndarray       # (N,) decision fed INTO this round
    events: List[str]
    wall_s: float
    coord_s: float
    # churn-regime fields (defaults = the fault-free run)
    present: Optional[np.ndarray] = None    # (N,) trained this round
    reported: Optional[np.ndarray] = None   # (N,) report met the deadline
    staleness: Optional[np.ndarray] = None  # (N,) rounds since last
    #                                         participation, post-round
    coordinated: bool = True           # False on a quorum miss (decision
    #                                    re-applied, not recomputed)
    sim_delay_s: float = 0.0           # straggler-delay model, simulated
    # hier-regime fields: on the two-tier surface `stats` holds the
    # (S, 2*#tensors) pod-cluster centroids, `val_acc`/`assignments`/
    # `centers` are per summary ROW (S = pods * k_local), and these two
    # complete the pulled upload (the coordinator replay inputs)
    counts: Optional[np.ndarray] = None     # (S,) reporting-member counts
    valsums: Optional[np.ndarray] = None    # (S,) summed member val accs


@dataclass
class FleetRunResult:
    history: List[FleetRoundLog]
    n_compiles: int                    # always 1 — the acceptance property
    comm: dict                         # per-round ledger (launch.comm)
    params: Any                        # final client-stacked params (on mesh)
    compile_s: float = 0.0
    meta: dict = field(default_factory=dict)

    @property
    def mean_val_accs(self):
        return [r.mean_val_acc for r in self.history]


def make_unit_fleet(n_clients: int = 8, *, arch: str = "squeezenet-dr",
                    image_size: int = 16, data_scale: int = 16,
                    seed: int = 0, lr: float = 2e-3):
    """Unit-scale fleet: the first ``n_clients`` Table-I clinics, one
    per pod slot of :func:`make_fleet_mesh` (one clinic per device on
    the 8-device CPU stand-in). Returns ``(model, opt, mesh,
    clients_data)`` — the arguments :func:`run_fleet` wants."""
    table = scale_table(data_scale)[:, :n_clients]
    clients = make_dr_swarm_data(image_size=image_size, seed=seed,
                                 table=table)
    model = build_model(get_config(arch))
    opt = make_optimizer(OptimizerConfig(name="adam", lr=lr))
    return model, opt, make_fleet_mesh(len(clients)), clients


def _sample_round_batch(model_cfg, clients_data, n_rows: int, seed: int,
                        round_idx: int):
    """Host-side per-round batch upload: every client draws ``n_rows``
    uniform-with-replacement rows from its own train split — the same
    distribution as the engine's on-device per-step sampler, stacked as
    the (N, n_rows, ...) round batch the fleet step slices per step."""
    Xs, ys = [], []
    for i, c in enumerate(clients_data):
        rng = np.random.default_rng([seed, round_idx, i])
        X, y = c["train"]
        idx = rng.integers(0, len(y), size=n_rows)
        Xs.append(X[idx])
        ys.append(y[idx])
    return make_batch(model_cfg, np.stack(Xs), np.stack(ys))


def export_fleet_checkpoint(path, model, sparams, clusters, weights, *,
                            round_idx: int, n_clusters: int,
                            mean_val_acc: float = 0.0, present=None):
    """Serialize the swarm state for ``repro.serve``.

    Applies the round's pending Eq. 2 (the aggregation the NEXT round
    would fold in) so the checkpoint holds each client's cluster
    aggregate, then saves the client-stacked tree with a manifest
    ``extra`` sufficient to rebuild the model serve-side with no
    training code: the full ``ModelConfig`` asdict, client count,
    |D_h| weights and the cluster decision.

    ``present`` (optional (N,) bool) switches the pending Eq. 2 onto the
    churn-masked variant with ``weights`` taken as the *effective*
    (staleness-decayed) weights — the exact aggregation the next driver
    round would execute, so a churn-regime checkpoint matches what the
    swarm would actually serve. ``None`` keeps the plain aggregate.
    """
    w = jnp.asarray(weights, jnp.float32)
    if present is None:
        agg = cluster_fedavg(sparams, jnp.asarray(clusters), w,
                             k=len(np.asarray(clusters)))
    else:
        agg = cluster_fedavg_masked(sparams, jnp.asarray(clusters), w,
                                    jnp.asarray(present, bool),
                                    k=len(np.asarray(clusters)))
    save_checkpoint(path, agg, step=round_idx + 1, extra={
        "model_config": asdict(model.cfg),
        "n_clients": int(len(np.asarray(clusters))),
        "client_weights": np.asarray(weights, np.float32).tolist(),
        "assignments": np.asarray(clusters, np.int32).tolist(),
        "n_clusters": int(n_clusters),
        "mean_val_acc": float(mean_val_acc),
    })


def run_fleet(model, opt, mesh, clients_data, *, rounds: int,
              local_steps: int = 4, batch_size: int = 8, lr: float = 2e-3,
              n_clusters: int = 3, p1: float = 0.9, p2: float = 0.8,
              kmeans_iters: int = 20, seed: int = 0,
              use_pallas_stats: bool = False, eval_batch: int = 64,
              eval_buckets: int = 0, bucket_strategy: str = "pow2",
              ckpt_path=None, ckpt_every: int = 0,
              faults: Optional[FleetFaults] = None,
              hier_k_local: int = 0,
              verbose: bool = False) -> FleetRunResult:
    """Drive ``rounds`` full BSO-SL rounds on ``mesh`` with exactly ONE
    compiled fleet-round executable.

    The round step is lowered and compiled once (AOT) with donated
    params/opt buffers; every round re-invokes the same executable with
    the freshly uploaded batch and the previous round's host cluster
    decision. Round 0 feeds ``singleton_assignments`` (Eq. 2 is the
    bitwise identity), so the executed protocol sequence matches the
    sim engine's round for round — see the module docstring.

    ``eval_buckets > 0`` switches val scoring onto the bucketed ragged
    layout: clients are grouped into size buckets
    (:func:`repro.data.dr.bucket_clients` on the val-split sizes), each
    bucket's eval stack is padded only to its own ceiling, and the
    driver compiles ONE fixed-shape eval program per bucket signature
    (round program built ``with_loss`` — no rectangular val stack rides
    the mesh). The compile budget becomes ``1 + n_buckets`` executables
    total, still zero per-round retraces, and the per-client accuracies
    are identical to the in-program rectangular eval (same
    post-local-phase params, same masked reduction —
    ``tests/test_fleet.py`` pins the parity).

    ``faults`` (a :class:`FleetFaults` with any knob active) switches
    the driver onto the churn regime — still ONE compiled executable:
    the round program is built ``with_churn`` (two extra (N,) bool
    operands) and the host injects per-round Bernoulli drops and
    straggler delays, applies the quorum rule to the coordinator, and
    carries the staleness counters that decay the Eq. 2 weights. Because
    the fleet aggregates FIRST, round ``r``'s incoming Eq. 2 uses round
    ``r-1``'s presence mask and post-round staleness — exactly the sim
    engine's churn semantics shifted by the pending-aggregation offset.
    An all-knobs-off ``FleetFaults()`` (or ``None``) keeps the
    churn-free program.

    ``hier_k_local > 0`` switches the driver onto the HIERARCHICAL
    two-tier regime (exclusive with ``eval_buckets`` — the hier round
    carries its own in-program eval): each mesh pod runs a local
    ``hier_k_local``-means over its clients' stats in-program, the
    driver pulls ONLY the O(pods * k_local)
    :class:`~repro.core.engine.HierRoundOut` summaries, and
    :func:`host_hier_coordinator` answers with the (S,) pod-cluster ->
    global-cluster map ``g`` that the next round composes on-mesh via
    ``g[a_local]`` (``a_local`` is fed back device-to-device, never
    pulled until a checkpoint export). Host traffic and host compute
    become O(pods), not O(clients) — the scaling claim
    ``BENCH_hier.json`` measures. Under ``faults`` the straggler
    exclusion moves IN-PROGRAM (a third ``report`` mask gates the pod
    k-means and summary sums); there is no host-side last-seen report
    cache — that cache is O(clients), the very thing this regime
    removes — so a straggler's stats simply sit out the round instead
    of being replayed stale (documented semantic difference from the
    flat churn regime).
    """
    N = len(clients_data)
    if n_clusters > N:
        raise ValueError(f"n_clusters={n_clusters} > n_clients={N}")
    hier = hier_k_local > 0
    bucketed = eval_buckets > 0
    if hier and bucketed:
        raise ValueError("hier_k_local and eval_buckets are exclusive "
                         "driver regimes (the hier round carries its own "
                         "in-program eval)")
    n_pods = int(mesh.shape["pod"]) if hier else 0
    S = n_pods * hier_k_local
    if hier and n_clusters > S:
        raise ValueError(
            f"n_clusters={n_clusters} > pods*k_local={S}: the global tier "
            "clusters the summary rows — raise hier_k_local or use more "
            "pods")
    churn = faults is not None and faults.active
    program = fleet_setup(model, opt, mesh, k=N, n_local_steps=local_steps,
                          use_pallas_stats=use_pallas_stats,
                          with_eval=not bucketed and not hier,
                          with_loss=bucketed,
                          donate=True, spmd="shard_map",
                          with_churn=churn, hier_k_local=hier_k_local)
    n_masks = (3 if hier else 2) if churn else 0
    in_sh = (program.in_shardings[:-n_masks] if n_masks
             else program.in_shardings)
    if hier:
        _, _, bsh, vsh, lsh, gsh, ush, csh, ash, kmsh, wsh = in_sh
    elif bucketed:
        _, _, bsh, lsh, csh, wsh = in_sh
    else:
        _, _, bsh, vsh, lsh, csh, wsh = in_sh
    msh = program.in_shardings[-1] if churn else None
    lr_arr = jax.device_put(jnp.float32(lr), lsh)

    with mesh, use_sharding(mesh, program.rules):
        keys = jax.random.split(jax.random.PRNGKey(seed), N)
        psh, osh = program.in_shardings[0], program.in_shardings[1]
        sparams = jax.jit(lambda ks: jax.vmap(model.init)(ks),
                          out_shardings=psh)(keys)
        sopt = jax.jit(lambda p: jax.vmap(opt.init)(p),
                       out_shardings=osh)(sparams)
        eval_progs = []
        if bucketed:
            # one fixed-shape eval program per bucket: gather the
            # bucket's client params, score its own-ceiling val stack
            rep = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec())
            groups = bucket_clients(
                [len(c["val"][1]) for c in clients_data],
                max_buckets=eval_buckets, strategy=bucket_strategy)
            ev = make_client_eval(model)
            for ids in groups:
                ids_arr = np.asarray(ids)
                val_b = jax.device_put(
                    stack_eval_split(model.cfg,
                                     [clients_data[i] for i in ids],
                                     "val", batch=eval_batch), rep)
                fn = jax.jit(lambda p, v, _ids=ids_arr: ev(
                    jax.tree.map(lambda x: x[_ids], p), v))
                eval_progs.append((ids_arr, val_b, fn))
        else:
            val = jax.device_put(
                stack_eval_split(model.cfg, clients_data, "val",
                                 batch=eval_batch), vsh)
        base_w = np.asarray([c["n_train"] for c in clients_data],
                            np.float32)
        weights = jax.device_put(base_w, wsh)
        clusters = np.asarray(singleton_assignments(N))
        if hier:
            # device-resident coordinator plumbing: the O(N) singleton
            # fallback and the assignment feedback never cross the host
            # boundary — only the (S,) decision g rides back per round
            clusters0_dev = jax.device_put(clusters.astype(np.int32), csh)
            a_prev = jax.device_put(np.zeros(N, np.int32), ash)
            g = np.zeros(S, np.int32)

        # churn-regime host state: staleness counters (rounds since last
        # participation), the previous round's presence (the pending
        # Eq. 2's receive mask — all-ones before round 0), and the
        # coordinator's last-seen report cache for stragglers (flat
        # regime only — the hier surface excludes stragglers in-program)
        staleness = np.zeros(N, np.int32)
        prev_present = np.ones(N, bool)
        have_cache = np.zeros(N, bool)
        last_stats, last_val = None, None
        centers = np.full(n_clusters, -1, np.int32)   # no decision yet

        def eff_weights():
            # |D_h| * λ^staleness — λ=0 is the hard mask (0^0 == 1
            # keeps fresh clients at full weight, matching the engine's
            # jnp.power semantics bitwise for integer exponents)
            return base_w * np.power(np.float32(faults.stale_decay),
                                     staleness.astype(np.float32))

        def put_batch(r):
            batch = _sample_round_batch(model.cfg, clients_data,
                                        local_steps * batch_size, seed, r)
            return jax.device_put(batch, bsh)

        # ONE lowering -> ONE executable for every round
        t0 = time.perf_counter()
        batch0 = put_batch(0)
        mask_ops = ()
        if churn:
            ones = jax.device_put(np.ones(N, bool), msh)
            mask_ops = (ones,) * n_masks
        if hier:
            lowered = program.jit_fn.lower(
                sparams, sopt, batch0, val, lr_arr,
                jax.device_put(g, gsh), jax.device_put(jnp.asarray(False),
                                                       ush),
                clusters0_dev, a_prev,
                jax.device_put(jax.random.fold_in(jax.random.PRNGKey(seed),
                                                  0), kmsh),
                weights, *mask_ops)
        elif bucketed:
            lowered = program.jit_fn.lower(
                sparams, sopt, batch0, lr_arr,
                jax.device_put(clusters, csh), weights, *mask_ops)
        else:
            lowered = program.jit_fn.lower(
                sparams, sopt, batch0, val, lr_arr,
                jax.device_put(clusters, csh), weights, *mask_ops)
        compiled = lowered.compile()
        compile_s = time.perf_counter() - t0
        batch_bytes = sum(x.size * x.dtype.itemsize
                          for x in jax.tree.leaves(batch0))
        params_abs = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        if hier:
            comm = hier_round_comm(compiled, params_abs, N, n_pods=n_pods,
                                   k_local=hier_k_local,
                                   batch_bytes=batch_bytes)
        else:
            comm = fleet_round_comm(compiled, params_abs, N,
                                    batch_bytes=batch_bytes)

        history = []
        for r in range(rounds):
            t0 = time.perf_counter()
            # round 0 re-uploads the batch the lowering used (sampling is
            # deterministic per (seed, r)) so every round's wall_s covers
            # the same work: sample + upload + round step + stat pull
            batch = put_batch(r)
            applied = g.copy() if hier else clusters
            extra = ()
            present = straggler = reported = None
            if churn:
                present, straggler = draw_faults(faults, N, seed, r)
                reported = present & ~straggler
                # the incoming Eq. 2 is the PREVIOUS round's pending
                # aggregation: its receive mask is last round's presence
                # and its weights carry last round's post-round staleness
                weights = jax.device_put(eff_weights(), wsh)
                extra = (jax.device_put(present, msh),
                         jax.device_put(prev_present, msh))
                if hier:
                    # third mask: stragglers train but miss the summary
                    # deadline — excluded from the pod k-means in-program
                    extra = extra + (jax.device_put(reported, msh),)
            if hier:
                sparams, sopt, out = compiled(
                    sparams, sopt, batch, val, lr_arr,
                    jax.device_put(g, gsh),
                    jax.device_put(jnp.asarray(r > 0), ush),
                    clusters0_dev, a_prev,
                    jax.device_put(
                        jax.random.fold_in(jax.random.PRNGKey(seed), r),
                        kmsh),
                    weights, *extra)
                # the ONLY device->host pull: the O(pods) summaries —
                # a_local stays on-mesh as next round's a_prev operand
                stats = np.asarray(out.centroids)
                counts = np.asarray(out.counts)
                valsums = np.asarray(out.valsums)
                val_acc = _hier_val_means(counts, valsums)
                train_loss = float(out.train_loss)
                hier_mean_val = float(out.mean_val)
                a_prev = out.a_local
            elif bucketed:
                sparams, sopt, stats_dev, loss_dev = compiled(
                    sparams, sopt, batch, lr_arr,
                    jax.device_put(applied, csh), weights, *extra)
                stats = np.asarray(stats_dev)
                # per-bucket scoring of the returned post-local-phase
                # params — the same protocol point as the in-program eval
                val_acc = np.zeros(N, np.float32)
                for ids_arr, val_b, fn in eval_progs:
                    val_acc[ids_arr] = np.asarray(fn(sparams, val_b))
                train_loss = float(loss_dev)
            else:
                sparams, sopt, out = compiled(
                    sparams, sopt, batch, val, lr_arr,
                    jax.device_put(applied, csh), weights, *extra)
                # the ONLY device->host pull: the tiny FleetRoundOut
                stats = np.asarray(out.stats)
                val_acc = np.asarray(out.val_acc)
                train_loss = float(out.train_loss)
            t1 = time.perf_counter()
            coordinated = True
            events: List[str] = []
            if churn:
                # post-round state: presence resets staleness, absence
                # accrues it; this round's mask gates the NEXT Eq. 2
                staleness = np.where(present, 0, staleness + 1) \
                    .astype(np.int32)
                prev_present = present
                n_rep = int(reported.sum())
            if hier:
                if churn and faults.quorum and n_rep < faults.quorum:
                    coordinated = False
                    events = [f"quorum miss: {n_rep}/{N} reported "
                              f"< Q={faults.quorum}; previous pod-cluster "
                              "map re-applied"]
                else:
                    g, centers, events = host_hier_coordinator(
                        stats, counts, valsums, k=n_clusters, p1=p1,
                        p2=p2, kmeans_iters=kmeans_iters, seed=seed,
                        round_idx=r)
            elif churn:
                # the coordinator sees fresh reports only from clients
                # that met the deadline; stragglers/dropped fall back to
                # their last-seen report (a dropped client's params are
                # frozen, so its freshly computed stats equal its stale
                # ones — no information leak either way)
                stats_used, val_used = stats.copy(), val_acc.copy()
                if last_stats is not None:
                    miss = ~reported & have_cache
                    stats_used[miss] = last_stats[miss]
                    val_used[miss] = last_val[miss]
                else:
                    last_stats = np.zeros_like(stats)
                    last_val = np.zeros_like(val_acc)
                last_stats[reported] = stats[reported]
                last_val[reported] = val_acc[reported]
                have_cache |= reported
                if faults.quorum and n_rep < faults.quorum:
                    # quorum miss: re-apply the previous decision (round
                    # 0's singleton fallback included) — deterministic,
                    # and the skipped coordinator stream is simply never
                    # drawn for this round_idx
                    coordinated = False
                    events = [f"quorum miss: {n_rep}/{N} reported "
                              f"< Q={faults.quorum}; previous cluster "
                              "decision re-applied"]
                else:
                    clusters, centers, events = host_coordinator(
                        stats_used, val_used, k=n_clusters, p1=p1, p2=p2,
                        kmeans_iters=kmeans_iters, seed=seed, round_idx=r)
            else:
                clusters, centers, events = host_coordinator(
                    stats, val_acc, k=n_clusters, p1=p1, p2=p2,
                    kmeans_iters=kmeans_iters, seed=seed, round_idx=r)
            t2 = time.perf_counter()
            log = FleetRoundLog(
                round=r,
                mean_val_acc=hier_mean_val if hier
                else float(val_acc.mean()),
                val_acc=val_acc, train_loss=train_loss,
                stats=stats,
                assignments=g.copy() if hier else clusters,
                centers=centers,
                applied_clusters=applied, events=list(events),
                wall_s=t1 - t0, coord_s=t2 - t1,
                present=present, reported=reported,
                staleness=staleness.copy() if churn else None,
                coordinated=coordinated,
                sim_delay_s=float(faults.delay_s) if churn
                and bool(straggler.any()) else 0.0,
                counts=counts if hier else None,
                valsums=valsums if hier else None)
            history.append(log)
            if ckpt_path and ckpt_every and (r + 1) % ckpt_every == 0:
                # when ckpt_every divides rounds, the _r{rounds} export
                # is bitwise the final export below — same params, same
                # decision, same (effective) weights. A hier export is
                # the ONE place the (N,) assignments are materialised on
                # host: compose g[a_local] from the device feedback.
                export_fleet_checkpoint(
                    f"{ckpt_path}_r{r + 1}", model, sparams,
                    g[np.asarray(a_prev)] if hier else clusters,
                    eff_weights() if churn else base_w, round_idx=r,
                    n_clusters=n_clusters, mean_val_acc=log.mean_val_acc,
                    present=present if churn else None)
            if verbose:
                flag = "" if coordinated else " [quorum miss]"
                decision = g if hier else clusters
                print(f"[fleet] round {r}: val_acc={log.mean_val_acc:.3f} "
                      f"loss={log.train_loss:.3f} "
                      f"clusters={np.bincount(decision, minlength=n_clusters)}"
                      f" events={len(events)} wall={log.wall_s:.2f}s{flag}")

    if ckpt_path:
        if history:
            # final export: fold in the pending Eq. 2 (see module
            # docstring) — under churn, the masked variant with the
            # staleness-decayed weights the next round would apply. On
            # the hier surface the (N,) decision is composed here from
            # the device-resident feedback (the one a_local pull).
            export_fleet_checkpoint(
                ckpt_path, model, sparams,
                g[np.asarray(a_prev)] if hier
                else history[-1].assignments,
                eff_weights() if churn else base_w, round_idx=rounds - 1,
                n_clusters=n_clusters,
                mean_val_acc=history[-1].mean_val_acc,
                present=prev_present if churn else None)
        else:
            # rounds=0 used to silently skip the export; save the
            # initial swarm under the identity Eq. 2 instead so the
            # caller always gets the checkpoint it asked for
            warnings.warn(
                "run_fleet(rounds=0) with ckpt_path: no rounds executed "
                "— exporting the initial (untrained) swarm params under "
                "the singleton identity Eq. 2", stacklevel=2)
            export_fleet_checkpoint(
                ckpt_path, model, sparams, clusters, base_w,
                round_idx=-1, n_clusters=n_clusters, mean_val_acc=0.0)
    meta = dict(n_clients=N, rounds=rounds, local_steps=local_steps,
                batch_size=batch_size, lr=lr, n_clusters=n_clusters, p1=p1,
                p2=p2, seed=seed, mesh_shape=dict(mesh.shape),
                n_devices=mesh.size,
                eval_buckets=len(eval_progs) if bucketed else 0,
                hier=None if not hier else {
                    "k_local": hier_k_local, "n_pods": n_pods,
                    "summary_rows": S},
                faults=None if faults is None else {
                    "drop_rate": faults.drop_rate,
                    "straggler_rate": faults.straggler_rate,
                    "delay_s": faults.delay_s,
                    "stale_decay": faults.stale_decay,
                    "quorum": faults.quorum})
    # measured, not asserted: the AOT `compiled` path performs exactly the
    # one .compile() above, and any (future) direct jit_fn dispatches
    # would land in its trace cache — so this catches a regression that
    # reintroduces per-round retracing. Bucketed eval adds exactly one
    # compiled program per bucket signature (their jit caches never grow
    # past 1 — same shapes every round).
    n_compiles = (1 + program.jit_fn._cache_size()
                  + sum(fn._cache_size() for _, _, fn in eval_progs))
    return FleetRunResult(history=history, n_compiles=n_compiles, comm=comm,
                          params=sparams, compile_s=compile_s, meta=meta)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--data-scale", type=int, default=16)
    ap.add_argument("--image-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--devices", type=int, default=8,
                    help="CPU stand-in device count (0 = leave backend "
                         "alone)")
    ap.add_argument("--pallas-stats", action="store_true")
    ap.add_argument("--eval-buckets", type=int, default=0,
                    help="bucket the val eval into at most this many "
                         "size buckets (0 = rectangular in-program eval)")
    ap.add_argument("--ckpt", default=None, metavar="PATH",
                    help="export the final aggregated swarm params "
                         "(npz + manifest) for repro.serve")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="also export every N rounds (PATH_r<N>)")
    ap.add_argument("--drop-rate", type=float, default=0.0,
                    help="per-round Bernoulli client-drop probability "
                         "(fault injection; 0 = churn-free)")
    ap.add_argument("--straggler-rate", type=float, default=0.0,
                    help="probability a present client reports late")
    ap.add_argument("--straggler-delay", type=float, default=0.0,
                    help="simulated straggler report delay in seconds "
                         "(logged, never slept)")
    ap.add_argument("--stale-decay", type=float, default=0.0,
                    help="λ of the staleness-weighted Eq. 2 "
                         "(0 = hard participation mask)")
    ap.add_argument("--quorum", type=int, default=0,
                    help="coordinator quorum Q: recompute clusters only "
                         "when >= Q clients report (0 = always)")
    ap.add_argument("--hier-k", type=int, default=0,
                    help="per-pod local k-means cluster count: > 0 "
                         "switches onto the two-tier O(pods) coordinator "
                         "(0 = flat O(clients))")
    args = ap.parse_args()
    if args.devices:
        force_host_device_count(args.devices)
    model, opt, mesh, clients = make_unit_fleet(
        args.clients, image_size=args.image_size,
        data_scale=args.data_scale, seed=args.seed)
    faults = FleetFaults(drop_rate=args.drop_rate,
                         straggler_rate=args.straggler_rate,
                         delay_s=args.straggler_delay,
                         stale_decay=args.stale_decay,
                         quorum=args.quorum)
    res = run_fleet(model, opt, mesh, clients, rounds=args.rounds,
                    local_steps=args.local_steps,
                    batch_size=args.batch_size, seed=args.seed,
                    use_pallas_stats=args.pallas_stats,
                    eval_buckets=args.eval_buckets,
                    ckpt_path=args.ckpt, ckpt_every=args.ckpt_every,
                    faults=faults if faults.active else None,
                    hier_k_local=args.hier_k,
                    verbose=True)
    if args.ckpt:
        print(f"[fleet] checkpoint -> {args.ckpt}.npz")
    coll = res.comm["eq2_collective_bytes"]["total"]
    if args.hier_k:
        up = res.comm["summary_upload_bytes"]
        what = (f"summary upload {up} B "
                f"({res.comm['summary_rows']} rows) to host")
    else:
        up = res.comm["stat_upload_bytes"]
        what = f"stat upload {up} B to host"
    print(f"[fleet] {res.meta['n_clients']} clients on "
          f"{res.meta['n_devices']} devices, {args.rounds} rounds, "
          f"{res.n_compiles} compile ({res.compile_s:.1f}s); per round: "
          f"{what}, Eq.2 collectives {coll} B/device")


if __name__ == "__main__":
    main()
