"""Production mesh definitions (TPU v5e pods).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run sets XLA_FLAGS before any jax initialisation.
"""
from __future__ import annotations

import jax

# per-chip hardware constants (TPU v5e) used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (256 chips) single pod; (2,16,16)=512 chips multi-pod.

    Axes: pod  — swarm-client / outer-DP axis (multi-pod only)
          data — batch + FSDP axis
          model — tensor/expert-parallel axis
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_clients: int = 1):
    """Sim-regime mesh (single CPU device) — used only by tests that
    exercise shard_map code paths with a trivial mesh."""
    return jax.make_mesh((1,), ("clients",))
