"""Production mesh definitions (TPU v5e pods).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run sets XLA_FLAGS before any jax initialisation.
"""
from __future__ import annotations

import jax

# per-chip hardware constants (TPU v5e) used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (256 chips) single pod; (2,16,16)=512 chips multi-pod.

    Axes: pod  — swarm-client / outer-DP axis (multi-pod only)
          data — batch + FSDP axis
          model — tensor/expert-parallel axis
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_fleet_mesh(n_clients: int):
    """Unit-scale fleet mesh: the ``pod`` (swarm-client) axis spread
    over however many local devices divide ``n_clients``; ``data`` and
    ``model`` stay size 1 (CNN-sized clients are not sharded within a
    pod). On the 8-device CPU stand-in with 8 clients this is one
    client per device — the miniature of the production (2,16,16)
    mesh's pod axis; on a single device it degrades to a trivial mesh
    so the same driver code runs under plain pytest."""
    n_dev = len(jax.devices())
    n_pod = max(d for d in range(1, n_dev + 1) if n_clients % d == 0)
    return jax.make_mesh((n_pod, 1, 1), ("pod", "data", "model"))


def make_host_mesh(n_clients: int = 1):
    """Sim-regime mesh (single CPU device) — used only by tests that
    exercise shard_map code paths with a trivial mesh."""
    return jax.make_mesh((1,), ("clients",))
