import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture x input shape)
# on the production meshes with 512 placeholder host devices.
#
# For each combination this emits a JSON artifact with
# ``memory_analysis()``, ``cost_analysis()`` and the collective-bytes
# census parsed from the optimized HLO — the §Roofline inputs.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
#
# NOTE: the two lines above MUST run before any other import — jax locks
# the device count at first initialisation.

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.configs.base import ModelConfig, OptimizerConfig, ShapeConfig
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh
from repro.models.model import build_model, cache_specs, input_specs
from repro.optim.optimizers import make_optimizer
from repro.sharding import build_param_specs, use_sharding
from repro.sharding.rules import spec_for
from repro.train.steps import make_serve_step, make_train_step

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts"

# the HLO collective census lives in repro.launch.comm (side-effect
# free, shared with the fleet driver's traffic ledger); re-exported
# here because this module historically owned it
from repro.launch.comm import collective_bytes  # noqa: E402,F401


# ---------------------------------------------------------------------------
# per-arch runtime overrides for the production run


def runtime_config(arch_id: str, shape: ShapeConfig,
                   optimized: bool = False) -> ModelConfig:
    """Production runtime settings. ``optimized`` applies the KEPT §Perf
    hillclimb variants on top of the paper-faithful baseline:
    grouped MoE dispatch (H1), vocab padding + q-chunk 256 (H2),
    fp8 KV cache for decode (H3)."""
    cfg = get_config(arch_id)
    big = arch_id in ("kimi-k2-1t-a32b", "llama4-maverick-400b-a17b",
                      "deepseek-67b", "command-r-35b")
    overrides = dict(
        dtype="bfloat16",
        scan_layers=True,
        remat="full" if shape.kind == "train" else "none",
    )
    # long-context decode: dense/moe/vlm attention archs run the documented
    # sliding-window serving mode; ssm/hybrid are natively O(1)-state.
    if shape.name == "long_500k" and cfg.family in ("dense", "moe", "vlm"):
        overrides["sliding_window"] = 8192
    if big:
        overrides["param_dtype"] = "bfloat16"
    if optimized:
        if cfg.n_experts:
            overrides["moe_grouped_dispatch"] = True           # §Perf H1
        if cfg.vocab_size % 128:
            overrides["vocab_round_to"] = 128                   # §Perf H2
        overrides["attn_chunk_q"] = 256                         # §Perf H2
        if shape.kind == "decode" and cfg.n_heads:
            overrides["cache_dtype"] = "float8_e4m3fn"          # §Perf H3
    return dataclasses.replace(cfg, **overrides)


def optimizer_for(cfg: ModelConfig) -> OptimizerConfig:
    if cfg.param_dtype == "bfloat16":
        # >=100B-class configs: factored optimizer states
        return OptimizerConfig(name="adafactor", lr=1e-3, grad_clip=1.0)
    return OptimizerConfig(name="adamw", lr=3e-4, weight_decay=0.1)


def microbatches_for(cfg: ModelConfig, shape: ShapeConfig,
                     n_dp: int = 16) -> int:
    """Grad-accumulation steps. The per-microbatch batch MUST stay
    divisible by the data-parallel extent (pod x data), otherwise the
    batch axis silently under-shards and per-device activations blow up
    by the lost factor (§Perf H4: this exact bug cost 6x memory on the
    2x16x16 mesh before the divisibility guard)."""
    if shape.kind != "train":
        return 0
    B = shape.global_batch
    n_mb = min(cfg.microbatch_override or 16, B)
    while n_mb > 1 and (B // n_mb) % n_dp:
        n_mb //= 2
    return n_mb


# ---------------------------------------------------------------------------


def model_flops(cfg: ModelConfig, shape: ShapeConfig, n_params: int,
                n_active: int) -> float:
    """6*N*D (train) / 2*N*D (forward) with active params for MoE."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch     # decode: 1 token/seq


def active_params(cfg: ModelConfig, params_abs) -> tuple:
    n_total = sum(int(x.size) for x in jax.tree.leaves(params_abs))
    if cfg.n_experts and cfg.top_k:
        # subtract inactive expert weights
        def expert_leaves(t):
            out = 0
            flat, _ = jax.tree_util.tree_flatten_with_path(t)
            for path, leaf in flat:
                ps = "/".join(str(getattr(k, "key", k)) for k in path)
                if "experts/" in ps:
                    out += int(leaf.size)
            return out
        n_exp = expert_leaves(params_abs)
        n_active = n_total - n_exp + int(n_exp * cfg.top_k / cfg.n_experts)
    else:
        n_active = n_total
    return n_total, n_active


def rules_for(cfg: ModelConfig):
    """AxisRules honouring cfg.fsdp_over_pod (§Perf H4)."""
    from repro.sharding.rules import AxisRules, DEFAULT_LOGICAL_TO_PHYSICAL
    if cfg.fsdp_over_pod:
        return AxisRules(dict(DEFAULT_LOGICAL_TO_PHYSICAL))
    table = dict(DEFAULT_LOGICAL_TO_PHYSICAL)
    table["p_embed"] = ("data",)        # weights stay intra-pod
    return AxisRules(table)


def build_lowered(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                  microbatches: int):
    """Lower train/prefill/serve for one config on one mesh."""
    model = build_model(cfg)
    rules = rules_for(cfg)
    params_abs = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    psh = jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s),
                       build_param_specs(params_abs, mesh, rules))
    specs = input_specs(cfg, shape)

    def in_sharding_for(spec):
        ax = ("batch",) + (None,) * (len(spec.shape) - 1)
        return jax.sharding.NamedSharding(mesh, spec_for(ax, mesh, spec.shape, rules))

    with mesh, use_sharding(mesh, rules):
        if shape.kind == "train":
            opt = make_optimizer(optimizer_for(cfg))
            opt_abs = jax.eval_shape(opt.init, params_abs)
            osh = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(mesh, s),
                build_param_specs(opt_abs, mesh, rules))
            step = make_train_step(model, opt, microbatches=microbatches)
            batch_sh = {k: in_sharding_for(v) for k, v in specs.items()}
            lowered = jax.jit(
                step,
                in_shardings=(psh, osh, batch_sh, None),
                out_shardings=(psh, osh, None),
                donate_argnums=(0, 1),
            ).lower(params_abs, opt_abs, specs,
                    jax.ShapeDtypeStruct((), jnp.float32))
        elif shape.kind == "prefill":
            def prefill(params, batch):
                logits, _ = model.forward(params, batch)
                return logits
            batch_sh = {k: in_sharding_for(v) for k, v in specs.items()}
            lowered = jax.jit(
                prefill, in_shardings=(psh, batch_sh),
            ).lower(params_abs, specs)
        else:  # decode
            cache_abs = cache_specs(cfg, shape)
            csh = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(mesh, s),
                build_param_specs(cache_abs, mesh, rules))
            serve = make_serve_step(model)
            tok_sh = in_sharding_for(specs["tokens"])
            lowered = jax.jit(
                serve,
                in_shardings=(psh, tok_sh, csh, None),
                out_shardings=(None, None, csh),
                donate_argnums=(2,),
            ).lower(params_abs, specs["tokens"], cache_abs,
                    jax.ShapeDtypeStruct((), jnp.int32))
    return lowered, params_abs


# ---------------------------------------------------------------------------
# cost probes
#
# XLA's ``cost_analysis()`` does NOT multiply while-loop (scan) bodies by
# their trip count, so the full scanned+microbatched lowering under-reports
# flops/bytes/collectives by ~L x n_mb. The probe strategy: lower the SAME
# config UNROLLED at two small layer counts L1 < L2 (single microbatch),
# read exact top-level costs, and extrapolate linearly in depth:
#     cost(L) = c(L1) + (c(L2) - c(L1)) / (L2 - L1) * (L - L1)
# A third probe at n_mb=2 measures the per-extra-microbatch collective /
# byte overhead (FSDP weight re-gathers), added (n_mb - 1) times.
# Memory analysis always comes from the REAL (scanned, microbatched)
# compile — XLA's buffer assignment handles loops correctly.


def _probe_layers(cfg: ModelConfig):
    if cfg.family == "moe":
        period = max(cfg.moe_every, 1)
    elif cfg.family == "hybrid":
        period = cfg.attn_every or 1
    else:
        period = 1
    base = cfg.n_dense_layers
    return base + period, base + 2 * period


def _probe_cfg(cfg: ModelConfig, n_layers: int) -> ModelConfig:
    kw = dict(n_layers=n_layers, scan_layers=False)
    if cfg.is_encoder_decoder:
        kw["n_encoder_layers"] = n_layers
    return dataclasses.replace(cfg, **kw)


def _costs_of(compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll["total"])}


def cost_probe(cfg: ModelConfig, shape: ShapeConfig, mesh, n_mb: int) -> dict:
    L1, L2 = _probe_layers(cfg)
    lowered1, _ = build_lowered(_probe_cfg(cfg, L1), shape, mesh, microbatches=0)
    c1 = _costs_of(lowered1.compile())
    lowered2, _ = build_lowered(_probe_cfg(cfg, L2), shape, mesh, microbatches=0)
    c2 = _costs_of(lowered2.compile())

    L = cfg.n_layers
    out = {}
    for k in ("flops", "bytes", "coll"):
        per_layer = (c2[k] - c1[k]) / max(L2 - L1, 1)
        out[k] = c1[k] + per_layer * (L - L1)

    if n_mb > 1:
        # per-extra-microbatch overhead (weight re-gather traffic)
        lowered_mb, _ = build_lowered(_probe_cfg(cfg, L1), shape, mesh,
                                      microbatches=2)
        cmb = _costs_of(lowered_mb.compile())
        for k in ("bytes", "coll"):
            delta = max(cmb[k] - c1[k], 0.0) * (L / L1)
            out[k] += delta * (n_mb - 1)
    out["probe_layers"] = (L1, L2)
    return out


def lower_one(arch_id: str, shape_name: str, multi_pod: bool,
              verbose: bool = True, overrides: dict = None,
              tag: str = "", optimized: bool = False) -> dict:
    """overrides/tag: §Perf hillclimb variants — config deltas applied on
    top of the production runtime config, artifact saved under the tag.
    optimized=True applies all KEPT hillclimb variants (tag 'opt')."""
    shape = INPUT_SHAPES[shape_name]
    cfg = runtime_config(arch_id, shape, optimized=optimized)
    if optimized and not tag:
        tag = "opt"
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    rec = {"arch": arch_id, "shape": shape_name, "tag": tag,
           "overrides": overrides or {},
           "mesh": "2x16x16" if multi_pod else "16x16", "ok": False}
    t0 = time.time()

    n_dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    mb = microbatches_for(cfg, shape, n_dp)
    lowered, params_abs = build_lowered(cfg, shape, mesh, microbatches=mb)
    n_total, n_active = active_params(cfg, params_abs)
    rec["n_params"] = n_total
    rec["n_active_params"] = n_active
    rec["microbatches"] = mb

    rec["lower_s"] = round(time.time() - t0, 1)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 1)

    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
        "code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
    }
    rec["memory"]["peak_per_device"] = (
        rec["memory"]["argument_bytes"] + rec["memory"]["output_bytes"]
        + rec["memory"]["temp_bytes"] - rec["memory"]["alias_bytes"])

    # raw full-model census (under-counts loop bodies — kept for reference)
    rec["hlo_raw"] = _costs_of(compiled)
    rec["collectives"] = collective_bytes(compiled.as_text())

    # probe-extrapolated per-device costs (see comment above cost_probe)
    t2 = time.time()
    probe = cost_probe(cfg, shape, mesh, mb)
    rec["probe_s"] = round(time.time() - t2, 1)
    rec["cost"] = {"flops_per_device": probe["flops"],
                   "bytes_per_device": probe["bytes"],
                   "collective_bytes_per_device": probe["coll"],
                   "probe_layers": probe["probe_layers"]}
    flops_dev, bytes_dev, coll_dev = probe["flops"], probe["bytes"], probe["coll"]

    # --- roofline terms (seconds) ---
    mf = model_flops(cfg, shape, n_total, n_active)
    t_compute = flops_dev / PEAK_FLOPS_BF16
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    rec["roofline"] = {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": flops_dev * n_chips,
        "useful_flops_ratio": mf / max(flops_dev * n_chips, 1.0),
    }
    rec["ok"] = True
    if verbose:
        r = rec["roofline"]
        print(f"[dryrun] {arch_id:28s} {shape_name:12s} {rec['mesh']:8s} "
              f"compile={rec['compile_s']:6.1f}s peak/dev="
              f"{rec['memory']['peak_per_device']/2**30:7.2f}GiB "
              f"Tc={r['t_compute_s']:.3e} Tm={r['t_memory_s']:.3e} "
              f"Tcoll={r['t_collective_s']:.3e} dom={r['dominant']} "
              f"useful={r['useful_flops_ratio']:.2f}")
    return rec


def save(rec: dict):
    ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
    tag = f"_{rec['tag']}" if rec.get("tag") else ""
    name = f"dryrun_{rec['arch']}_{rec['shape']}_{rec['mesh']}{tag}.json"
    (ARTIFACT_DIR / name).write_text(json.dumps(rec, indent=1))


def shape_applicable(arch_id: str, shape_name: str) -> bool:
    # whisper-base skips long_500k (DESIGN.md §Arch-applicability)
    if arch_id == "whisper-base" and shape_name == "long_500k":
        return False
    return True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--continue-on-error", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the kept §Perf variants (artifacts tagged _opt)")
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            if not shape_applicable(arch, shape):
                print(f"[dryrun] {arch} {shape}: SKIP (documented)")
                continue
            for mp in meshes:
                try:
                    rec = lower_one(arch, shape, mp, optimized=args.optimized)
                    save(rec)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"[dryrun] {arch} {shape} multi_pod={mp} FAILED: {e}")
                    traceback.print_exc()
                    if not args.continue_on_error:
                        raise
    if failures:
        print(f"{len(failures)} failures")
        raise SystemExit(1)
    print("dry-run complete: all combinations lowered and compiled.")


if __name__ == "__main__":
    main()
