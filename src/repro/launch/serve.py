"""Batched serving driver: prefill a batch of prompts, then decode
tokens autoregressively with the KV/SSM cache via serve_step.

PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.train.steps import make_serve_step


def prefill_into_cache(model, params, prompts, cache):
    """Teacher-force the prompt through decode steps (smoke-scale;
    production prefill uses the chunked forward + cache writeback)."""
    B, P = prompts.shape
    step = jax.jit(make_serve_step(model))
    last = None
    for t in range(P):
        last, _, cache = step(params, prompts[:, t:t + 1], cache,
                              jnp.asarray(t, jnp.int32))
    return last, cache


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    print(f"[serve] arch={cfg.arch_id} params={model.param_count(params):,}")

    max_seq = args.prompt_len + args.tokens + 1
    cache = model.init_cache(args.batch, max_seq)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab_size)
    tok, cache = prefill_into_cache(model, params, prompts, cache)

    step = jax.jit(make_serve_step(model))
    out = [tok]
    t0 = time.time()
    for i in range(args.tokens):
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        tok, _, cache = step(params, out[-1][:, None], cache, pos)
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.stack(out[1:], axis=1)
    print(f"decoded {args.tokens} tokens x {args.batch} seqs "
          f"in {dt:.2f}s ({args.tokens * args.batch / dt:.1f} tok/s)")
    print("sample:", gen[0].tolist())


if __name__ == "__main__":
    main()
