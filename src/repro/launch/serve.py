"""Batched serving driver (compat shim over ``repro.serve``).

MIGRATION: production serving lives in ``repro.serve`` — the
continuous-batching engine (slot scheduler, per-bucket compiled
chunked-prefill + decode programs, ``flash_decode`` under
``use_pallas``). This module remains as

* :func:`prefill_into_cache` — the per-token teacher-forcing reference
  that the chunked prefill is validated against (and the only prefill
  for cache families without one: ssm / hybrid / encdec);
* :func:`run_serve` — a one-call driver that routes attention-backed
  LMs through the engine and everything else through the per-token
  loop, so the old CLI keeps working for every ``--arch``.

PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.train.steps import make_serve_step


def prefill_into_cache(model, params, prompts, cache):
    """Teacher-force the prompt through decode steps (smoke-scale;
    production prefill uses the chunked forward + cache writeback —
    ``model.prefill`` via ``repro.serve``)."""
    B, P = prompts.shape
    step = jax.jit(make_serve_step(model))
    last = None
    for t in range(P):
        last, _, cache = step(params, prompts[:, t:t + 1], cache,
                              jnp.asarray(t, jnp.int32))
    return last, cache


def run_serve(arch: str, *, batch: int = 4, prompt_len: int = 8,
              tokens: int = 16, seed: int = 0, smoke: bool = True,
              engine: str = "auto", verbose: bool = False):
    """Generate ``tokens`` greedy tokens for ``batch`` random prompts.

    ``engine="auto"`` uses the ``repro.serve`` continuous-batching
    engine when the family has a chunked-prefill path and falls back
    to the per-token loop otherwise; ``"loop"`` forces the fallback.
    Returns ``(gen, info)`` — the (batch, tokens) int32 generations and
    a stats dict (tok/s, path taken).
    """
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    if verbose:
        print(f"[serve] arch={cfg.arch_id} "
              f"params={model.param_count(params):,}")
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab_size),
        np.int32)

    use_engine = engine == "engine" or (engine == "auto"
                                        and model.prefill is not None)
    t0 = time.time()
    if use_engine:
        from repro.serve import BucketSpec, generate
        res = generate(model, params, list(prompts),
                       max_new_tokens=tokens,
                       buckets=(BucketSpec(batch, prompt_len + tokens + 1),))
        gen = np.asarray([r.tokens for r in res], np.int32)
    else:
        max_seq = prompt_len + tokens + 1
        cache = model.init_cache(batch, max_seq)
        tok, cache = prefill_into_cache(model, params,
                                        jnp.asarray(prompts), cache)
        step = jax.jit(make_serve_step(model))
        out = [tok]                      # prefill argmax = first token
        for i in range(tokens - 1):
            pos = jnp.asarray(prompt_len + i, jnp.int32)
            tok, _, cache = step(params, out[-1][:, None], cache, pos)
            out.append(tok)
        gen = np.asarray(jnp.stack(out, axis=1), np.int32)
    dt = time.time() - t0
    info = {"path": "engine" if use_engine else "loop",
            "tok_per_s": tokens * batch / max(dt, 1e-9), "wall_s": dt}
    if verbose:
        print(f"decoded {tokens} tokens x {batch} seqs in {dt:.2f}s "
              f"({info['tok_per_s']:.1f} tok/s, {info['path']} path)")
        print("sample:", gen[0].tolist())
    return gen, info


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", choices=("auto", "engine", "loop"),
                    default="auto")
    args = ap.parse_args()
    run_serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
              tokens=args.tokens, seed=args.seed, engine=args.engine,
              verbose=True)


if __name__ == "__main__":
    main()
