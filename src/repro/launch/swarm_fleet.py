"""Fleet-regime BSO-SL: the paper's protocol as a multi-pod collective
program, lowered from the SAME round body as the sim regime
(``repro.core.engine.make_fleet_round``).

One swarm client per pod; within a pod the client's model is FSDP/TP-
sharded over (data, model). The round's communication:

  * distribution-stat upload  -> computed INSIDE the round program
    (``param_stats_batched`` under ``--pallas-stats``, the jnp oracle
    otherwise) and returned as a tiny (clients, 2*#tensors) matrix —
    the paper's communication-efficiency claim riding the same ICI/DCN
    collective as the round step instead of a separate host pass
  * intra-cluster FedAvg Eq.2 -> cluster-masked traffic over "pod"
    (client-to-client, no server): ``cluster_fedavg`` segment-sum, with
    XLA SPMD inserting the cross-pod collectives. (The explicit
    masked-psum shard_map formulation in core.aggregation is the same
    math and is exercised at unit scale in tests; XLA's partitioner
    cannot yet mix manual "pod" collectives with auto-sharded gathers
    at 512 devices — this is the one deliberate aggregation choice.)

The coordinator decisions (k-means + brain storm) stay host-side — they
are O(clients) on the uploaded stats and correspond to the paper's
neighbour-assignment server. This module lowers+compiles the fleet
round step on the 2x16x16 mesh — the beyond-paper "swarm-on-pods"
dry-run artifact.
"""
import argparse
import os

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import OptimizerConfig
from repro.core.engine import make_fleet_round
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.optim.optimizers import make_optimizer
from repro.sharding import build_param_specs, use_sharding


def force_host_device_count(n: int = 512):
    """Opt into the n-device CPU stand-in. Deliberately NOT a module
    side effect: only the CLI entrypoint calls this, so importing this
    module (tests, examples) never poisons the process-wide backend.
    Must run before jax initialises its backend to take effect."""
    os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
        f" --xla_force_host_platform_device_count={n}"


def lower_fleet_round(arch_id: str = "granite-3-2b", k: int = 3,
                      seq: int = 1024, per_client_batch: int = 16,
                      use_pallas_stats: bool = False):
    cfg = get_config(arch_id)
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="bfloat16", scan_layers=True,
                              remat="full")
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=True)
    n_clients = mesh.shape["pod"]
    opt = make_optimizer(OptimizerConfig(name="adamw", lr=3e-4))

    params_abs = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    opt_abs = jax.eval_shape(opt.init, params_abs)

    def stack(t):
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct((n_clients,) + x.shape, x.dtype), t)

    sparams, sopt = stack(params_abs), stack(opt_abs)
    batch_abs = {
        "tokens": jax.ShapeDtypeStruct((n_clients, per_client_batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((n_clients, per_client_batch, seq), jnp.int32),
    }
    clusters_abs = jax.ShapeDtypeStruct((n_clients,), jnp.int32)
    weights_abs = jax.ShapeDtypeStruct((n_clients,), jnp.float32)

    round_step = make_fleet_round(model, opt, k,
                                  use_pallas=use_pallas_stats)

    # inner (per-client) sharding must not consume the "pod" axis — that
    # is the client axis in the fleet regime
    from repro.sharding.rules import AxisRules, DEFAULT_LOGICAL_TO_PHYSICAL
    inner_rules = AxisRules({
        kk: tuple(a for a in v if a != "pod")
        for kk, v in DEFAULT_LOGICAL_TO_PHYSICAL.items()})

    with mesh, use_sharding(mesh, inner_rules):
        psh = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, P(*("pod",) + tuple(s))),
            build_param_specs(params_abs, mesh, inner_rules))
        osh = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, P(*("pod",) + tuple(s))),
            build_param_specs(opt_abs, mesh, inner_rules))
        bsh = jax.tree.map(
            lambda x: jax.sharding.NamedSharding(mesh, P("pod", "data")),
            batch_abs)
        rsh = jax.sharding.NamedSharding(mesh, P())
        # the uploaded stats matrix is O(clients * #tensors) — sharded
        # over the client axis like everything else in the round
        ssh = jax.sharding.NamedSharding(mesh, P("pod"))
        lowered = jax.jit(
            round_step,
            in_shardings=(psh, osh, bsh, None, rsh, rsh),
            out_shardings=(psh, osh, ssh),
        ).lower(sparams, sopt, batch_abs,
                jax.ShapeDtypeStruct((), jnp.float32),
                clusters_abs, weights_abs)
        compiled = lowered.compile()
    return lowered, compiled


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--pallas-stats", action="store_true",
                    help="serve the in-round stat upload with the "
                         "param_stats_batched kernel (TPU; CPU runs it "
                         "in interpret mode)")
    args = ap.parse_args()
    force_host_device_count(512)
    _, compiled = lower_fleet_round(args.arch,
                                    use_pallas_stats=args.pallas_stats)
    mem = compiled.memory_analysis()
    print(f"[swarm-fleet] {args.arch} round step compiled on 2x16x16; "
          f"temp/dev={int(mem.temp_size_in_bytes)/2**30:.2f} GiB")


if __name__ == "__main__":
    main()
