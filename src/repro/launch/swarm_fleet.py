import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=512"

# Fleet-regime BSO-SL: the paper's protocol as a multi-pod collective
# program. One swarm client per pod; within a pod the client's model is
# FSDP/TP-sharded over (data, model). The round's communication:
#
#   * distribution-stat upload  -> tiny all_gather over "pod"
#     (O(#tensors) floats — the paper's communication-efficiency claim
#     as an ICI/DCN collective)
#   * intra-cluster FedAvg Eq.2 -> cluster-masked psum over "pod"
#     (client-to-client traffic, no server)
#
# The coordinator decisions (k-means + brain storm) stay host-side —
# they are O(clients) and correspond to the paper's neighbour-assignment
# server. This module lowers+compiles the fleet round step on the
# 2x16x16 mesh — the beyond-paper "swarm-on-pods" dry-run artifact.

import argparse

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import INPUT_SHAPES, OptimizerConfig
from repro.core.aggregation import cluster_psum_fedavg
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.models.model import input_specs
from repro.optim.optimizers import make_optimizer
from repro.sharding import build_param_specs, use_sharding
from repro.train.steps import make_train_step


def make_fleet_round(model, opt, k: int, n_local_steps: int = 1):
    """Fleet round as a pure-jit program: vmap over the client (pod)
    axis for local training, then Eq.2 cluster aggregation as a
    segment-sum over clients. XLA SPMD inserts the cross-pod collectives
    (the masked-psum shard_map formulation in core.aggregation is
    exercised at unit scale; XLA's partitioner cannot yet mix manual
    "pod" collectives with auto-sharded gathers at 512 devices)."""
    step = make_train_step(model, opt)

    def round_step(sparams, sopt, batch, lr, clusters, weights):
        def local(p, o, b):
            # slice a fresh microbatch per local step — training
            # n_local_steps times on the identical batch is not SGD.
            # ceil-sized microbatches with a clamped final start cover
            # every row (indivisible batches overlap slightly at the
            # tail instead of silently dropping rows).
            n_b = jax.tree.leaves(b)[0].shape[0]
            mb = min(n_b, -(-n_b // n_local_steps))

            def one(i, carry):
                pp, oo = carry
                start = jnp.minimum(i * mb, n_b - mb)
                bi = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(x, start, mb, 0), b)
                pp, oo, _ = step(pp, oo, bi, lr)
                return (pp, oo)
            return jax.lax.fori_loop(0, n_local_steps, one, (p, o))

        sparams, sopt = jax.vmap(local)(sparams, sopt, batch)
        from repro.core.aggregation import cluster_fedavg
        sparams = cluster_fedavg(sparams, clusters, weights, k)
        return sparams, sopt

    return round_step


def lower_fleet_round(arch_id: str = "granite-3-2b", k: int = 3,
                      seq: int = 1024, per_client_batch: int = 16):
    cfg = get_config(arch_id)
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="bfloat16", scan_layers=True,
                              remat="full")
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=True)
    n_clients = mesh.shape["pod"]
    opt = make_optimizer(OptimizerConfig(name="adamw", lr=3e-4))

    params_abs = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    opt_abs = jax.eval_shape(opt.init, params_abs)

    def stack(t):
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct((n_clients,) + x.shape, x.dtype), t)

    sparams, sopt = stack(params_abs), stack(opt_abs)
    batch_abs = {
        "tokens": jax.ShapeDtypeStruct((n_clients, per_client_batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((n_clients, per_client_batch, seq), jnp.int32),
    }
    clusters_abs = jax.ShapeDtypeStruct((n_clients,), jnp.int32)
    weights_abs = jax.ShapeDtypeStruct((n_clients,), jnp.float32)

    round_step = make_fleet_round(model, opt, k)

    # inner (per-client) sharding must not consume the "pod" axis — that
    # is the client axis in the fleet regime
    from repro.sharding.rules import AxisRules, DEFAULT_LOGICAL_TO_PHYSICAL
    inner_rules = AxisRules({
        kk: tuple(a for a in v if a != "pod")
        for kk, v in DEFAULT_LOGICAL_TO_PHYSICAL.items()})

    with mesh, use_sharding(mesh, inner_rules):
        psh = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, P(*("pod",) + tuple(s))),
            build_param_specs(params_abs, mesh, inner_rules))
        osh = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, P(*("pod",) + tuple(s))),
            build_param_specs(opt_abs, mesh, inner_rules))
        bsh = jax.tree.map(
            lambda x: jax.sharding.NamedSharding(mesh, P("pod", "data")),
            batch_abs)
        rsh = jax.sharding.NamedSharding(mesh, P())
        lowered = jax.jit(
            round_step,
            in_shardings=(psh, osh, bsh, None, rsh, rsh),
            out_shardings=(psh, osh),
        ).lower(sparams, sopt, batch_abs,
                jax.ShapeDtypeStruct((), jnp.float32),
                clusters_abs, weights_abs)
        compiled = lowered.compile()
    return lowered, compiled


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    args = ap.parse_args()
    _, compiled = lower_fleet_round(args.arch)
    mem = compiled.memory_analysis()
    print(f"[swarm-fleet] {args.arch} round step compiled on 2x16x16; "
          f"temp/dev={int(mem.temp_size_in_bytes)/2**30:.2f} GiB")


if __name__ == "__main__":
    main()
