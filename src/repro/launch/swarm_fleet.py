"""Fleet-regime BSO-SL: the paper's protocol as a multi-pod collective
program, lowered from the SAME round body as the sim regime
(``repro.core.engine.make_fleet_round``).

One swarm client per pod; within a pod the client's model is FSDP/TP-
sharded over (data, model). The round's communication:

  * distribution-stat upload  -> computed INSIDE the round program
    (``param_stats_batched`` under ``--pallas-stats``, the jnp oracle
    otherwise) and returned as a tiny (clients, 2*#tensors) matrix —
    the paper's communication-efficiency claim riding the same ICI/DCN
    collective as the round step instead of a separate host pass
  * intra-cluster FedAvg Eq.2 -> cluster-masked traffic over "pod"
    (client-to-client, no server): ``cluster_fedavg`` segment-sum, with
    XLA SPMD inserting the cross-pod collectives. (The explicit
    masked-psum shard_map formulation in core.aggregation is the same
    math and is exercised at unit scale in tests; XLA's partitioner
    cannot yet mix manual "pod" collectives with auto-sharded gathers
    at 512 devices — this is the one deliberate aggregation choice.)

The coordinator decisions (k-means + brain storm) stay host-side — they
are O(clients) on the uploaded stats and correspond to the paper's
neighbour-assignment server. This module lowers+compiles the fleet
round step on the 2x16x16 mesh — the beyond-paper "swarm-on-pods"
dry-run artifact.
"""
import argparse
import os
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import OptimizerConfig
from repro.core.engine import FleetRoundOut, HierRoundOut, make_fleet_round
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.optim.optimizers import make_optimizer
from repro.sharding import build_param_specs, use_sharding
from repro.sharding.rules import AxisRules, DEFAULT_LOGICAL_TO_PHYSICAL


def force_host_device_count(n: int = 512):
    """Opt into the n-device CPU stand-in. Deliberately NOT a module
    side effect: only the CLI entrypoint calls this, so importing this
    module (tests, examples) never poisons the process-wide backend.
    Must run before jax initialises its backend to take effect."""
    os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
        f" --xla_force_host_platform_device_count={n}"


def fleet_inner_rules() -> AxisRules:
    """Per-client sharding rules: the ``pod`` axis is the swarm-client
    axis in the fleet regime, so the inner (within-client) model
    sharding must never consume it."""
    return AxisRules({
        kk: tuple(a for a in v if a != "pod")
        for kk, v in DEFAULT_LOGICAL_TO_PHYSICAL.items()})


class FleetProgram(NamedTuple):
    """The one compiled-surface contract shared by the dry-run lowering
    and the multi-round driver (see :func:`fleet_setup`)."""
    jit_fn: Any          # jax.jit-wrapped engine.make_fleet_round step
    rules: AxisRules     # inner rules — trace under use_sharding(mesh, rules)
    in_shardings: Any    # per-argument shardings (batch/val are prefix trees)
    out_shardings: Any


def fleet_setup(model, opt, mesh, *, k: int, n_local_steps: int = 1,
                use_pallas_stats: bool = False, with_eval: bool = False,
                with_loss: bool = False, donate: bool = False,
                spmd: str = "auto", with_churn: bool = False,
                hier_k_local: int = 0,
                hier_kmeans_iters: int = 20) -> FleetProgram:
    """ONE setup path for the fleet round on a ``pod``-axis mesh —
    the dry-run lowering (:func:`lower_fleet_round`) and the end-to-end
    driver (``repro.launch.fleet_driver``) both build their program
    here, so the two can never drift.

    Two partitioning strategies over the same
    ``engine.make_fleet_round`` body:

    * ``spmd="auto"`` (the LM dry-run path) — GSPMD auto-partitioning:
      every client-stacked argument is sharded ``P("pod", ...)``,
      params and opt state additionally carry the inner FSDP/TP spec
      from :func:`fleet_inner_rules`, and Eq. 2's segment-sum is
      partitioned by XLA into the cross-pod collectives.
    * ``spmd="shard_map"`` (the driver path) — manual ``pod``
      collectives: the round body runs on each shard's *local* client
      slice (``axis_name="pod"``) and Eq. 2 is the explicit masked-psum
      formulation (``aggregation.cluster_fedavg_psum``). This is the
      layout that serves vmapped-*conv* clients (the paper's CNNs):
      GSPMD cannot partition the grouped convolution a vmapped conv
      lowers to over the stacked-client axis, while under shard_map
      each shard sees a plain per-client conv. Inner model sharding is
      not used on this path (CNN clients are single-device sized).

    ``with_eval`` keeps the per-client val accuracies in-program over a
    rectangular stacked val split; ``with_loss`` is the bucketed-eval
    driver surface (``engine.make_fleet_round(with_loss=True)``): the
    round program carries no val stack — the driver evaluates with one
    fixed-shape compiled program per size bucket — and returns the
    replicated last-step loss alongside the stats.

    ``with_churn`` appends the fault-injection operands — two (N,)
    bool masks ``(present, agg_present)`` sharded over the client axis
    (see ``engine.make_fleet_round(with_churn=True)``); the driver's
    quorum/staleness regime feeds them per round, and all-ones masks
    reproduce the churn-free program bitwise.

    ``hier_k_local > 0`` selects the HIERARCHICAL round surface
    (``engine.make_fleet_round(hier_k_local=...)``, exclusive with
    ``with_eval``/``with_loss``): pod-local k-means runs on-mesh and
    only the O(pods * k_local) :class:`~repro.core.engine.HierRoundOut`
    summaries face the host. On the shard_map path each mesh shard is
    one pod (pod index = ``axis_index("pod")``); on the GSPMD path the
    client axis is split into ``mesh.shape["pod"]`` equal contiguous
    pods. The per-round host traffic drops from O(clients) stats to
    O(pods) summaries in both directions (the decision comes back as
    the (pods * k_local,) map ``g``; the (N,) fallback ``clusters0``
    and the assignment feedback ``a_prev``/``a_local`` stay
    device-resident) — the scaling claim ``BENCH_hier.json`` measures.
    ``with_churn`` here appends THREE masks ``(present, agg_present,
    report)`` — see the engine docstring for the straggler semantics.

    The coordinator inputs (``clusters``, ``weights``) ride the client
    axis and the stat upload comes back sharded over ``pod``.
    ``donate=True`` donates the params/opt buffers (the driver's round
    loop updates the swarm in place, round after round, without
    retracing — the jit-cache contract ``tests/test_fleet.py`` pins).

    Call :attr:`FleetProgram.jit_fn` (or ``.lower(...)`` it) inside
    ``with mesh, use_sharding(mesh, program.rules):`` so activation
    constraints resolve against the fleet mesh.
    """
    rules = fleet_inner_rules()
    rep = jax.sharding.NamedSharding(mesh, P())
    # the uploaded stats matrix is O(clients * #tensors) — sharded over
    # the client axis like everything else in the round
    ssh = jax.sharding.NamedSharding(mesh, P("pod"))

    if with_eval and with_loss:
        raise ValueError("with_eval and with_loss are exclusive round "
                         "surfaces")
    hier = hier_k_local > 0
    if hier and (with_eval or with_loss):
        raise ValueError("hier_k_local selects its own eval surface — "
                         "drop with_eval/with_loss")
    if spmd == "shard_map":
        from jax.experimental.shard_map import shard_map
        from repro.sharding import use_sharding
        inner_step = make_fleet_round(model, opt, k, n_local_steps,
                                      use_pallas=use_pallas_stats,
                                      with_eval=with_eval,
                                      with_loss=with_loss,
                                      axis_name="pod",
                                      with_churn=with_churn,
                                      hier_k_local=hier_k_local,
                                      hier_kmeans_iters=hier_kmeans_iters)

        def local_step(*args):
            # every mesh axis is manual inside the shard_map body, so
            # with_sharding_constraint is rejected there — disable the
            # activation-sharding context for the traced body (matters
            # for attention-family clients whose forward calls
            # shard_act; conv clients never hit it)
            with use_sharding(None):
                return inner_step(*args)

        pod = P("pod")
        if hier:
            # (params, opt, batch, val, lr, g, use_composed, clusters0,
            #  a_prev, kmkey, weights) — g/use_composed/kmkey replicated
            # (the O(pods) decision), the fallback + assignment feedback
            # device-resident on the client axis
            in_specs = (pod, pod, pod, pod, P(), P(), P(), pod, pod,
                        P(), pod)
            out_specs = (pod, pod, HierRoundOut(
                centroids=pod, counts=pod, wsums=pod, valsums=pod,
                a_local=pod, mean_val=P(), train_loss=P()))
        elif with_eval:
            in_specs = (pod, pod, pod, pod, P(), pod, pod)
            out_specs = (pod, pod, FleetRoundOut(stats=pod, val_acc=pod,
                                                 train_loss=P()))
        elif with_loss:
            in_specs = (pod, pod, pod, P(), pod, pod)
            out_specs = (pod, pod, pod, P())
        else:
            in_specs = (pod, pod, pod, P(), pod, pod)
            out_specs = (pod, pod, pod)
        if with_churn:
            # present, agg_present (+ report on the hier surface)
            in_specs = in_specs + ((pod, pod, pod) if hier
                                   else (pod, pod))
        # check_rep off: several conv/reduce-window primitives lack
        # replication rules in this jax version
        round_step = shard_map(local_step, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_rep=False)
        to_shard = lambda spec: rep if spec == P() else ssh
        in_sh = jax.tree.map(to_shard, in_specs,
                             is_leaf=lambda x: isinstance(x, P))
        out_sh = jax.tree.map(to_shard, out_specs,
                              is_leaf=lambda x: isinstance(x, P))
    else:
        params_abs = jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0)))
        opt_abs = jax.eval_shape(opt.init, params_abs)

        def stacked_shardings(tree_abs):
            return jax.tree.map(
                lambda s: jax.sharding.NamedSharding(
                    mesh, P(*("pod",) + tuple(s))),
                build_param_specs(tree_abs, mesh, rules))

        psh = stacked_shardings(params_abs)
        osh = stacked_shardings(opt_abs)
        # prefix shardings: one entry covers every batch/val leaf
        bsh = jax.sharding.NamedSharding(mesh, P("pod", "data"))
        round_step = make_fleet_round(model, opt, k, n_local_steps,
                                      use_pallas=use_pallas_stats,
                                      with_eval=with_eval,
                                      with_loss=with_loss,
                                      with_churn=with_churn,
                                      hier_k_local=hier_k_local,
                                      hier_pods=mesh.shape["pod"],
                                      hier_kmeans_iters=hier_kmeans_iters)
        if hier:
            # (params, opt, batch, val, lr, g, use_composed, clusters0,
            #  a_prev, kmkey, weights): client-axis operands sharded,
            # the O(pods) decision + summaries replicated
            in_sh = (psh, osh, bsh, ssh, None, rep, rep, ssh, ssh,
                     rep, rep)
            out_sh = (psh, osh, HierRoundOut(
                centroids=rep, counts=rep, wsums=rep, valsums=rep,
                a_local=ssh, mean_val=rep, train_loss=rep))
        elif with_eval:
            in_sh = (psh, osh, bsh, ssh, None, rep, rep)
            out_sh = (psh, osh, FleetRoundOut(stats=ssh, val_acc=ssh,
                                              train_loss=rep))
        elif with_loss:
            in_sh = (psh, osh, bsh, None, rep, rep)
            out_sh = (psh, osh, ssh, rep)
        else:
            in_sh = (psh, osh, bsh, None, rep, rep)
            out_sh = (psh, osh, ssh)
        if with_churn:
            # present, agg_present (+ report on the hier surface)
            in_sh = in_sh + ((rep, rep, rep) if hier else (rep, rep))
    jit_fn = jax.jit(round_step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0, 1) if donate else ())
    return FleetProgram(jit_fn=jit_fn, rules=rules, in_shardings=in_sh,
                        out_shardings=out_sh)


def lower_fleet_round(arch_id: str = "granite-3-2b", k: int = 3,
                      seq: int = 1024, per_client_batch: int = 16,
                      use_pallas_stats: bool = False):
    cfg = get_config(arch_id)
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="bfloat16", scan_layers=True,
                              remat="full")
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=True)
    n_clients = mesh.shape["pod"]
    opt = make_optimizer(OptimizerConfig(name="adamw", lr=3e-4))

    params_abs = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    opt_abs = jax.eval_shape(opt.init, params_abs)

    def stack(t):
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct((n_clients,) + x.shape, x.dtype), t)

    sparams, sopt = stack(params_abs), stack(opt_abs)
    batch_abs = {
        "tokens": jax.ShapeDtypeStruct((n_clients, per_client_batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((n_clients, per_client_batch, seq), jnp.int32),
    }
    clusters_abs = jax.ShapeDtypeStruct((n_clients,), jnp.int32)
    weights_abs = jax.ShapeDtypeStruct((n_clients,), jnp.float32)

    program = fleet_setup(model, opt, mesh, k=k,
                          use_pallas_stats=use_pallas_stats)
    with mesh, use_sharding(mesh, program.rules):
        lowered = program.jit_fn.lower(
            sparams, sopt, batch_abs,
            jax.ShapeDtypeStruct((), jnp.float32),
            clusters_abs, weights_abs)
        compiled = lowered.compile()
    return lowered, compiled


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--pallas-stats", action="store_true",
                    help="serve the in-round stat upload with the "
                         "param_stats_batched kernel (TPU; CPU runs it "
                         "in interpret mode)")
    args = ap.parse_args()
    force_host_device_count(512)
    _, compiled = lower_fleet_round(args.arch,
                                    use_pallas_stats=args.pallas_stats)
    mem = compiled.memory_analysis()
    print(f"[swarm-fleet] {args.arch} round step compiled on 2x16x16; "
          f"temp/dev={int(mem.temp_size_in_bytes)/2**30:.2f} GiB")


if __name__ == "__main__":
    main()
