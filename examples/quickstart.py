"""Quickstart: the full BSO-SL protocol on the synthetic DR swarm.

    PYTHONPATH=src python examples/quickstart.py

14 clinics (Table-I-exact class distribution, scaled for CPU),
SqueezeNet clients, 3 clusters, the paper's p1=0.9 / p2=0.8 — watch the
clustering, the brain-storm events and the mean test accuracy (Eq. 3).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import OptimizerConfig, SwarmConfig
from repro.core.swarm import SwarmTrainer
from repro.data.dr import TABLE_I, make_dr_swarm_data
from repro.models import build_model


def main():
    table = np.maximum(TABLE_I // 8, (TABLE_I > 0).astype(np.int64) * 2)
    clients = make_dr_swarm_data(image_size=16, seed=0, table=table)
    print(f"clinics: {len(clients)}, "
          f"train sizes: {[c['n_train'] for c in clients]}")

    model = build_model(get_config("squeezenet-dr"))
    swarm = SwarmConfig(n_clients=14, n_clusters=3, p1=0.9, p2=0.8,
                        rounds=5, local_steps=8)
    trainer = SwarmTrainer(model, clients, swarm,
                           OptimizerConfig(name="adam", lr=2e-3),
                           jax.random.PRNGKey(0), batch_size=8,
                           aggregation="bso")

    print(f"\nBSO-SL: {swarm.rounds} rounds, k={swarm.n_clusters}, "
          f"p1={swarm.p1}, p2={swarm.p2}")
    trainer.fit(jax.random.PRNGKey(1), verbose=True)

    acc = trainer.mean_accuracy("test")
    print(f"\nmean per-clinic test accuracy (paper Eq. 3): {acc:.4f}")
    last = trainer.history[-1]
    print(f"final clusters: {last.assignments.tolist()}")
    print(f"final centers:  {last.centers.tolist()}")


if __name__ == "__main__":
    main()
