"""Quickstart: the full BSO-SL protocol on the synthetic DR swarm.

    PYTHONPATH=src python examples/quickstart.py

14 clinics (Table-I-exact class distribution, scaled for CPU),
SqueezeNet clients, 3 clusters, the paper's p1=0.9 / p2=0.8.

Demonstrates the engine's three dispatch granularities:

1. the functional round engine — the whole multi-round protocol
   (local SGD with on-device batch sampling, distribution upload,
   k-means, the jax brain storm, Eq. 2 aggregation) as ONE scanned
   device program (``engine.run_rounds``),
2. the hyper-parameter grid — a k x p1 x p2 mini-ablation of the
   knobs the paper fixes, every point fit in ONE vmapped program
   (``baselines.run_grid_table`` over ``engine.run_grid``),
3. the stateful ``SwarmTrainer`` wrapper replaying the same protocol
   round-by-round with host-visible per-round logs.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import OptimizerConfig, SwarmConfig
from repro.core.baselines import run_grid_table
from repro.core.engine import (EngineConfig, jit_run_rounds, make_client_eval,
                               make_swarm_data, make_swarm_state,
                               stack_eval_split)
from repro.core.swarm import SwarmTrainer
from repro.data.dr import TABLE_I, make_dr_swarm_data
from repro.models import build_model
from repro.optim.optimizers import make_optimizer

ROUNDS = 5


def main():
    table = np.maximum(TABLE_I // 8, (TABLE_I > 0).astype(np.int64) * 2)
    clients = make_dr_swarm_data(image_size=16, seed=0, table=table)
    print(f"clinics: {len(clients)}, "
          f"train sizes: {[c['n_train'] for c in clients]}")

    model = build_model(get_config("squeezenet-dr"))
    opt = make_optimizer(OptimizerConfig(name="adam", lr=2e-3))

    # ---- the functional engine: ONE device program for all rounds ----
    cfg = EngineConfig(model=model, opt=opt, local_steps=8, batch_size=8,
                       lr=2e-3, aggregation="bso", n_clusters=3,
                       p1=0.9, p2=0.8)
    data = make_swarm_data(model.cfg, clients)
    state = make_swarm_state(model, opt, clients, jax.random.PRNGKey(0))

    print(f"\nBSO-SL engine: {ROUNDS} rounds scanned into one jit'd "
          f"program (k={cfg.n_clusters}, p1={cfg.p1}, p2={cfg.p2})")
    state, metrics = jit_run_rounds(state, data, cfg, ROUNDS)
    for r in range(ROUNDS):
        print(f"  round {r:3d} val_acc={float(metrics.mean_val_acc[r]):.4f} "
              f"loss={float(metrics.train_loss[r]):.4f} "
              f"replaces={int(metrics.n_replaced[r])} "
              f"swaps={int(metrics.n_swapped[r])}")

    veval = jax.jit(make_client_eval(model))
    test_acc = float(np.mean(np.asarray(
        veval(state.params, stack_eval_split(model.cfg, clients, "test")))))
    print(f"mean per-clinic test accuracy (paper Eq. 3): {test_acc:.4f}")
    print(f"final clusters: {np.asarray(metrics.assignments[-1]).tolist()}")
    print(f"final centers:  {np.asarray(metrics.centers[-1]).tolist()}")

    # ---- the grid engine: a k x p1 x p2 mini-ablation, ONE program ----
    swarm = SwarmConfig(n_clients=14, n_clusters=3, rounds=ROUNDS,
                        local_steps=8)
    axes = dict(k=(1, 3), p1=(0.9, 1.0), p2=(0.8,))
    print(f"\nGrid engine: {axes} — "
          f"{2 * 2 * 1} full fits vmapped into one executable")
    results, _ = run_grid_table(model, clients, swarm,
                                OptimizerConfig(name="adam", lr=2e-3),
                                jax.random.PRNGKey(2), axes=axes,
                                batch_size=8)
    for res in results:
        spec = ", ".join(f"{k}={v}" for k, v in res.items() if k != "acc")
        print(f"  {spec:<24s} test_acc={res['acc']:.4f}")

    # ---- the stateful wrapper: same protocol, per-round host logs ----
    swarm = SwarmConfig(n_clients=14, n_clusters=3, p1=0.9, p2=0.8,
                        rounds=ROUNDS, local_steps=8)
    trainer = SwarmTrainer(model, clients, swarm,
                           OptimizerConfig(name="adam", lr=2e-3),
                           jax.random.PRNGKey(0), batch_size=8,
                           aggregation="bso")
    print(f"\nSwarmTrainer wrapper (one engine dispatch per round):")
    trainer.fit(jax.random.PRNGKey(1), verbose=True)
    print(f"mean test accuracy: {trainer.mean_accuracy('test'):.4f}")


if __name__ == "__main__":
    main()
