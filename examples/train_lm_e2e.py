"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
on the synthetic non-IID token stream.

    PYTHONPATH=src python examples/train_lm_e2e.py                 # CI scale
    PYTHONPATH=src python examples/train_lm_e2e.py --preset 100m --steps 300

(At --preset 100m this is the paper-scale single-model run; the default
keeps CPU wall-time short while exercising the identical path.)
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny",
                    choices=list(train_mod.PRESETS))
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    ns = argparse.Namespace(
        preset=args.preset, steps=args.steps, batch=args.batch, seq=args.seq,
        lr=3e-3, seed=0, ckpt="/tmp/repro_lm_ckpt")
    import math
    from repro.launch.train import PRESETS
    final_ce = train_mod.run_single(ns)
    floor = math.log(PRESETS[args.preset]["vocab_size"])
    assert final_ce < 0.95 * floor, f"loss did not move ({final_ce} vs uniform {floor:.2f})"
    print(f"final CE {final_ce:.3f} (uniform floor {floor:.2f}) — "
          f"checkpoint at /tmp/repro_lm_ckpt.npz")


if __name__ == "__main__":
    main()
