"""Batched serving demo: prefill + autoregressive decode with the
KV/SSM cache for any assigned architecture (reduced variant on CPU).

    PYTHONPATH=src python examples/serve_batched.py --arch mamba2-370m
    PYTHONPATH=src python examples/serve_batched.py --arch granite-3-2b
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve as serve_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()
    sys.argv = ["serve", "--arch", args.arch, "--batch", str(args.batch),
                "--tokens", str(args.tokens)]
    serve_mod.main()


if __name__ == "__main__":
    main()
