"""Batched serving demo: prefill + autoregressive decode with the
KV/SSM cache for any assigned architecture (reduced variant on CPU).
Attention-backed LMs route through the ``repro.serve``
continuous-batching engine; SSM/hybrid/encdec use the per-token loop.

    PYTHONPATH=src python examples/serve_batched.py --arch mamba2-370m
    PYTHONPATH=src python examples/serve_batched.py --arch granite-3-2b
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import run_serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()
    run_serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
              tokens=args.tokens, verbose=True)


if __name__ == "__main__":
    main()
