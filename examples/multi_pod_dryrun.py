"""Example: lower + compile one (arch x shape) on the production meshes
and print its roofline terms. This is the per-combination unit of the
full dry-run matrix (`python -m repro.launch.dryrun --arch all ...`).

    PYTHONPATH=src python examples/multi_pod_dryrun.py \
        --arch granite-3-2b --shape decode_32k --mesh both
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: repro.launch.dryrun sets XLA_FLAGS for 512 host devices on import,
# before jax initialises.
from repro.launch import dryrun


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    args = ap.parse_args()
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for mp in meshes:
        rec = dryrun.lower_one(args.arch, args.shape, mp)
        dryrun.save(rec)


if __name__ == "__main__":
    main()
