"""Reproduce the paper's Table II and Table III (reduced CPU scale).

    PYTHONPATH=src python examples/paper_tables.py [--full]

--full uses the complete Table-I sample counts (3,657 images) — slower
but the faithful data scale.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import table2_methods, table3_archs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    scale = 1 if args.full else 8
    rounds = 8 if args.full else 4

    print("=== Table II: method comparison ===")
    print("name,us_per_call,derived")
    r2 = table2_methods.run(data_scale=scale, rounds=rounds)
    print("\npaper:      centralized 0.4118 | local 0.1924 | "
          "fedavg 0.3719 | bso-sl 0.3725")
    print("reproduced: " + " | ".join(f"{k} {v:.4f}" for k, v in r2.items()))

    print("\n=== Table III: model-agnostic sweep ===")
    print("name,us_per_call,derived")
    r3 = table3_archs.run(data_scale=scale, rounds=rounds)
    print("\npaper:      alexnet 0.3703 | vgg 0.4016 | "
          "inception 0.4216 | squeezenet 0.3725")
    print("reproduced: " + " | ".join(f"{k} {v:.4f}" for k, v in r3.items()))


if __name__ == "__main__":
    main()
