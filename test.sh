#!/usr/bin/env bash
# Tier-1 test entry point.
#
# Pins PYTHONPATH to the src layout and forces an 8-device CPU stand-in
# so the multi-device shard_map parity tests (e.g. cluster_fedavg vs
# cluster_psum_fedavg) run instead of skipping. Extra args pass through
# to pytest.
#
# Stage 1 is a fail-fast engine smoke: if the fused swarm_round program
# can't compile and run two rounds, nothing downstream is worth the
# full suite's wall time. Stage 2 is the sweep smoke: 2 rounds x 4
# Table-II methods must lower to ONE vmapped executable and run.
# Stage 3 is the grid smoke: the k x p1 hyper-parameter ablation must
# lower to ONE vmapped executable (compile-count asserted) and run.
# Stage 4 is the fleet smoke: 2 end-to-end driver rounds on the pod
# mesh (stats -> host k-means/BSA -> next round's clusters) with
# compile-count == 1 for the round step.
# Stage 5 is the churn smoke: the dropout x stale-decay scenario grid
# must lower to ONE vmapped executable with presence/staleness tracked.
# Stage 6 is the serve smoke: the continuous-batching engine drains a
# mixed-length workload with exactly one prefill + one decode
# executable per bucket.
set -euo pipefail
cd "$(dirname "$0")"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=8"
python -m pytest -x -q tests/test_engine.py::test_engine_smoke
python -m pytest -x -q tests/test_sweep.py::test_sweep_smoke_one_program
python -m pytest -x -q tests/test_grid.py::test_grid_smoke_one_program
python -m pytest -x -q tests/test_fleet.py::test_fleet_driver_smoke
python -m pytest -x -q tests/test_churn.py::test_churn_smoke_one_program
python -m pytest -x -q tests/test_serve.py::test_engine_smoke_program_budget
exec python -m pytest -x -q "$@"
