#!/usr/bin/env bash
# Tier-1 test entry point.
#
# Pins PYTHONPATH to the src layout and forces an 8-device CPU stand-in
# so the multi-device shard_map parity tests (e.g. cluster_fedavg vs
# cluster_psum_fedavg) run instead of skipping. Extra args pass through
# to pytest.
set -euo pipefail
cd "$(dirname "$0")"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=8"
exec python -m pytest -x -q "$@"
