"""Engine tests: the functional SwarmState round program (PR 2).

Covers the jax brain_storm port (shape invariants, numpy-oracle
statistical parity, same-key determinism), on-device batch sampling,
the single-jit'd-program property of swarm_round (compile/dispatch
count), scan-over-rounds consistency, the host-loop trajectory parity,
and the fleet round sharing the engine body with the stat upload
folded in.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import OptimizerConfig, SwarmConfig
from repro.core.bso import brain_storm, brain_storm_jax
from repro.core.diststats import swarm_distribution_matrix
from repro.core.engine import (EngineConfig, jit_run_rounds, jit_swarm_round,
                               make_fleet_round, make_swarm_data,
                               make_swarm_state, sample_local_batch,
                               swarm_round)
from repro.core.swarm import SwarmTrainer
from repro.data.dr import TABLE_I, make_dr_swarm_data
from repro.models import build_model
from repro.optim.optimizers import make_optimizer

SMALL_TABLE = np.maximum(TABLE_I // 16, (TABLE_I > 0).astype(np.int64) * 2)


@pytest.fixture(scope="module")
def dr_clients():
    return make_dr_swarm_data(image_size=16, seed=0, table=SMALL_TABLE)


@pytest.fixture(scope="module")
def dr_model():
    return build_model(get_config("squeezenet-dr"))


def _engine_pieces(model, clients, *, local_steps=2, aggregation="bso",
                   key=0):
    """(state, data, cfg) for a tiny engine run. State is built fresh
    per call — jit_swarm_round donates its buffers."""
    opt = make_optimizer(OptimizerConfig(name="adam", lr=2e-3))
    cfg = EngineConfig(model=model, opt=opt, local_steps=local_steps,
                       batch_size=8, lr=2e-3, aggregation=aggregation,
                       n_clusters=3, p1=0.9, p2=0.8, kmeans_iters=10)
    data = make_swarm_data(model.cfg, clients)
    state = make_swarm_state(model, opt, clients, jax.random.PRNGKey(key))
    return state, data, cfg


# -------------------------------------------------------- brain_storm (jax)


def test_brain_storm_jax_invariants_and_same_key_determinism():
    """For any (p1, p2): post-swap assignments are the same multiset of
    labels, every center is a member of its post-swap cluster, and the
    same key reproduces the identical plan bit-for-bit."""
    for seed in range(30):
        rng = np.random.default_rng(seed)
        n, k = 14, 3
        a0 = rng.integers(0, k, size=n).astype(np.int32)
        val = rng.uniform(size=n).astype(np.float32)
        p1, p2 = rng.uniform(), rng.uniform()
        key = jax.random.PRNGKey(seed)
        a, c, n_rep, n_swap = brain_storm_jax(key, a0, val, k, p1, p2)
        a_np, c_np = np.asarray(a), np.asarray(c)
        assert sorted(a_np.tolist()) == sorted(a0.tolist())
        for cl in range(k):
            if c_np[cl] >= 0:
                assert a_np[c_np[cl]] == cl
        a2, c2, n_rep2, n_swap2 = brain_storm_jax(key, a0, val, k, p1, p2)
        np.testing.assert_array_equal(a_np, np.asarray(a2))
        np.testing.assert_array_equal(c_np, np.asarray(c2))
        assert int(n_rep) == int(n_rep2) and int(n_swap) == int(n_swap2)


def test_brain_storm_jax_p1_p2_one_is_noop():
    """p1 = p2 = 1.0 => r > p never fires: assignments untouched, zero
    events, centers are the per-cluster best-validation members — the
    same guarantee the numpy oracle makes."""
    for seed in range(10):
        rng = np.random.default_rng(seed)
        n, k = 14, 3
        a0 = rng.integers(0, k, size=n).astype(np.int32)
        val = rng.uniform(size=n).astype(np.float32)
        a, c, n_rep, n_swap = brain_storm_jax(jax.random.PRNGKey(seed),
                                              a0, val, k, 1.0, 1.0)
        np.testing.assert_array_equal(np.asarray(a), a0)
        assert int(n_rep) == 0 and int(n_swap) == 0
        c_np = np.asarray(c)
        for cl in range(k):
            members = np.where(a0 == cl)[0]
            if len(members):
                assert c_np[cl] == members[np.argmax(val[members])]
            else:
                assert c_np[cl] == -1


def test_brain_storm_jax_statistical_parity_with_numpy_oracle():
    """The two implementations consume different RNG streams, so parity
    is statistical: over many keys/seeds the replacement and swap event
    rates must agree with the numpy oracle (and with the paper's
    ~(1-p1) / ~(1-p2) per-cluster disruption rates)."""
    jit_bs = jax.jit(brain_storm_jax, static_argnames=("k",))
    trials, k = 1500, 3
    reps_j = swaps_j = reps_n = swaps_n = 0
    for s in range(trials):
        rng = np.random.default_rng(s)
        a0 = rng.integers(0, k, size=14)
        val = rng.uniform(size=14).astype(np.float32)
        _, _, n_rep, n_swap = jit_bs(jax.random.PRNGKey(s), a0, val,
                                     k=k, p1=0.9, p2=0.8)
        reps_j += int(n_rep)
        swaps_j += int(n_swap)
        plan = brain_storm(rng, a0.copy(), val, k, 0.9, 0.8)
        reps_n += sum("replace" in e for e in plan.events)
        swaps_n += sum("swap" in e for e in plan.events)
    rep_j, rep_n = reps_j / (trials * k), reps_n / (trials * k)
    swap_j, swap_n = swaps_j / (trials * k), swaps_n / (trials * k)
    # ~0.1 minus no-op draws (new center == old center)
    assert 0.05 < rep_j < 0.15, rep_j
    assert abs(rep_j - rep_n) < 0.02, (rep_j, rep_n)
    # ~0.2 per-cluster initiation rate
    assert 0.10 < swap_j < 0.30, swap_j
    assert abs(swap_j - swap_n) < 0.02, (swap_j, swap_n)


# ------------------------------------------------------- on-device sampling


def test_sample_local_batch_never_draws_padding(dr_clients, dr_model):
    """Train sets are padded to the largest client with label=-1 poison
    rows; the bounded on-device sampler must never surface one."""
    data = make_swarm_data(dr_model.cfg, dr_clients)
    # padding exists (clinic sizes are skewed) and is poisoned
    assert int(jnp.min(data.train["labels"])) == -1
    for s in range(50):
        batch = sample_local_batch(jax.random.PRNGKey(s), data.train,
                                   data.train_n, 8)
        assert int(jnp.min(batch["labels"])) >= 0
        assert batch["labels"].shape == (len(dr_clients), 8)


def test_sample_local_batch_covers_each_clients_rows():
    """Sampling is uniform per client over [0, n_i): every real row is
    reachable (no off-by-one truncation) and no pad row ever is. Labels
    are the row index, so the sampled values ARE the drawn indices."""
    n_max, sizes = 10, [10, 3, 1]
    labels = np.stack([np.where(np.arange(n_max) < n, np.arange(n_max), -1)
                       for n in sizes]).astype(np.int32)
    train = {"images": jnp.zeros((3, n_max, 2, 2, 3), jnp.float32),
             "labels": jnp.asarray(labels)}
    train_n = jnp.asarray(sizes, jnp.int32)
    seen = [set() for _ in sizes]
    for s in range(300):
        batch = sample_local_batch(jax.random.PRNGKey(s), train, train_n, 4)
        got = np.asarray(batch["labels"])
        for i, n in enumerate(sizes):
            assert got[i].min() >= 0 and got[i].max() < n
            seen[i].update(got[i].tolist())
    for i, n in enumerate(sizes):
        assert seen[i] == set(range(n)), (i, seen[i])


# -------------------------------------------------- single-program property


def test_swarm_round_is_one_jitd_program(dr_clients, dr_model):
    """The acceptance property: a full BSO round (local steps + eval +
    stats + k-means + brain storm + Eq.2) lowers to ONE compiled XLA
    executable, and repeated rounds hit the jit cache (compile count 1,
    dispatch count 1 per round)."""
    state, data, cfg = _engine_pieces(dr_model, dr_clients)

    # one lowering == one device program for the entire round
    lowered = jax.jit(swarm_round, static_argnames=("cfg",)).lower(
        state, data, cfg)
    compiled = lowered.compile()
    s1, m1 = compiled(state, data)
    assert np.isfinite(float(m1.mean_val_acc))
    assert np.asarray(m1.assignments).shape == (len(dr_clients),)

    # the module-level entry point: exactly one compile, then cache hits
    n_before = jit_swarm_round._cache_size()
    s, m = jit_swarm_round(state, data, cfg)
    n_after_first = jit_swarm_round._cache_size()
    assert n_after_first <= n_before + 1
    for _ in range(3):
        s, m = jit_swarm_round(s, data, cfg)
    assert jit_swarm_round._cache_size() == n_after_first, \
        "swarm_round recompiled across rounds"
    assert int(s.round) == 4


def test_run_rounds_scan_matches_roundwise_calls(dr_clients, dr_model):
    """scan-over-rounds (one program for the whole fit) must reproduce
    the per-round dispatch trajectory: same key chain, same params,
    same metrics."""
    rounds = 3
    state_a, data, cfg = _engine_pieces(dr_model, dr_clients, key=3)
    state_b = jax.tree.map(jnp.copy, state_a)

    s, accs = state_a, []
    for _ in range(rounds):
        s, m = jit_swarm_round(s, data, cfg)
        accs.append(float(m.mean_val_acc))

    s_scan, ms = jit_run_rounds(state_b, data, cfg, rounds)
    np.testing.assert_allclose(np.asarray(ms.mean_val_acc),
                               np.asarray(accs, np.float32),
                               rtol=1e-4, atol=1e-5)
    assert int(s_scan.round) == int(s.round) == rounds
    for a, b in zip(jax.tree.leaves(s.params), jax.tree.leaves(s_scan.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-5)


def test_engine_same_key_same_trajectory(dr_clients, dr_model):
    """The engine is deterministic in its key: two trainers built and
    fit with identical keys produce bitwise-identical histories."""
    def run():
        swarm = SwarmConfig(n_clients=len(dr_clients), n_clusters=3,
                            rounds=2, local_steps=3)
        tr = SwarmTrainer(dr_model, dr_clients, swarm,
                          OptimizerConfig(name="adam", lr=2e-3),
                          jax.random.PRNGKey(11), batch_size=8,
                          aggregation="bso")
        tr.fit(jax.random.PRNGKey(12))
        return tr

    a, b = run(), run()
    for la, lb in zip(a.history, b.history):
        assert la.mean_val_acc == lb.mean_val_acc
        np.testing.assert_array_equal(la.assignments, lb.assignments)
        np.testing.assert_array_equal(la.centers, lb.centers)
    for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_engine_smoke(dr_clients, dr_model):
    """Fast tier-1 smoke (also run standalone by test.sh): two engine
    rounds produce finite, well-formed protocol artifacts."""
    state, data, cfg = _engine_pieces(dr_model, dr_clients, local_steps=2)
    state, m = jit_swarm_round(state, data, cfg)
    state, m = jit_swarm_round(state, data, cfg)
    assert np.isfinite(float(m.train_loss))
    assert 0.0 <= float(m.mean_val_acc) <= 1.0
    assert set(np.asarray(m.assignments).tolist()) <= {0, 1, 2}
    assert np.asarray(m.centers).shape == (3,)
    assert int(state.round) == 2


# ------------------------------------------- host-loop trajectory parity


def _host_loop_bso_fit(model, clients, *, rounds, local_steps, batch_size,
                       lr, seed):
    """Multi-round fit of the pre-engine host-driven round (PR 1
    semantics) — the single reference implementation shared with the
    fused-round benchmark. The engine must match this trajectory
    statistically."""
    from benchmarks.cluster_ablation import make_host_loop_round
    opt = make_optimizer(OptimizerConfig(name="adam", lr=lr))
    round_fn = make_host_loop_round(model, opt, clients,
                                    local_steps=local_steps,
                                    batch_size=batch_size, lr=lr)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(clients))
    params = jax.vmap(model.init)(keys)
    opt_state = jax.vmap(opt.init)(params)
    np_rng = np.random.default_rng(seed)
    fit_key = jax.random.PRNGKey(seed + 1)
    accs = []
    for _ in range(rounds):
        fit_key, sub = jax.random.split(fit_key)
        params, opt_state, acc = round_fn(params, opt_state, sub, np_rng)
        accs.append(acc)
    return accs


def test_engine_matches_host_loop_trajectory_statistically(dr_clients,
                                                           dr_model):
    """Acceptance: the fused engine round (jax brain storm + on-device
    sampling) learns the same trajectory as the host-loop reference —
    different RNG streams, so mean val-acc parity with tolerance, and
    both clear the 5-class random floor."""
    rounds, local_steps = 4, 10
    host = _host_loop_bso_fit(dr_model, dr_clients, rounds=rounds,
                              local_steps=local_steps, batch_size=8,
                              lr=2e-3, seed=0)
    swarm = SwarmConfig(n_clients=len(dr_clients), n_clusters=3,
                        rounds=rounds, local_steps=local_steps)
    tr = SwarmTrainer(dr_model, dr_clients, swarm,
                      OptimizerConfig(name="adam", lr=2e-3),
                      jax.random.PRNGKey(0), batch_size=8,
                      aggregation="bso")
    tr.fit(jax.random.PRNGKey(1))
    engine = [l.mean_val_acc for l in tr.history]
    # both learn past the 1/5 random floor by the end...
    assert np.mean(host[-2:]) > 0.25, host
    assert np.mean(engine[-2:]) > 0.25, engine
    # ...and the settled halves of the trajectories agree
    assert abs(np.mean(host[-2:]) - np.mean(engine[-2:])) < 0.2, \
        (host, engine)


# ------------------------------------------------------------ fleet sharing


def test_fleet_round_folds_param_stats_into_program():
    """make_fleet_round is built on the engine body: the distribution
    stat upload happens INSIDE the compiled round step, the Pallas
    param_stats_batched path matches the jnp oracle, and the whole
    round is one lowered executable."""
    cfg = get_config("granite-3-2b").smoke()
    model = build_model(cfg)
    opt = make_optimizer(OptimizerConfig(name="adam", lr=1e-2))
    n, B, S = 2, 4, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (n, B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    params = jax.vmap(model.init)(jax.random.split(jax.random.PRNGKey(0), n))
    sopt = jax.vmap(opt.init)(params)
    clusters = jnp.asarray([0, 1], jnp.int32)
    weights = jnp.ones((n,), jnp.float32)
    lr = jnp.float32(1e-2)

    round_step = make_fleet_round(model, opt, k=2, n_local_steps=2)
    # ONE compiled executable for local steps + stats + Eq.2
    compiled = jax.jit(round_step).lower(params, sopt, batch, lr,
                                         clusters, weights).compile()
    out_p, _, stats = compiled(params, sopt, batch, lr, clusters, weights)
    assert stats.shape[0] == n

    # stats are the §III.B upload of the post-local-step params;
    # singleton clusters make Eq.2 the identity, so check against the
    # oracle on the returned params
    expect = swarm_distribution_matrix(out_p, n)
    np.testing.assert_allclose(np.asarray(stats), np.asarray(expect),
                               rtol=1e-5, atol=1e-6)

    # the param_stats_batched kernel path, folded into the same program
    pallas_step = make_fleet_round(model, opt, k=2, n_local_steps=2,
                                   use_pallas=True)
    _, _, stats_pl = jax.jit(pallas_step)(params, sopt, batch, lr,
                                          clusters, weights)
    np.testing.assert_allclose(np.asarray(stats_pl), np.asarray(stats),
                               rtol=1e-4, atol=1e-5)
