import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_into, save_checkpoint


def test_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.int32)},
            "list": [jnp.zeros((2,)), jnp.full((3,), 7.0)]}
    save_checkpoint(tmp_path / "ckpt", tree, step=42, extra={"note": "x"})
    restored, step = restore_into(jax.tree.map(jnp.zeros_like, tree),
                                  tmp_path / "ckpt")
    assert step == 42
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shape_mismatch_raises(tmp_path):
    tree = {"a": jnp.ones((2, 2))}
    save_checkpoint(tmp_path / "c", tree)
    with pytest.raises(ValueError):
        restore_into({"a": jnp.ones((3, 3))}, tmp_path / "c")


def test_missing_leaf_raises(tmp_path):
    save_checkpoint(tmp_path / "c", {"a": jnp.ones((2,))})
    with pytest.raises(KeyError):
        restore_into({"a": jnp.ones((2,)), "b": jnp.ones((1,))}, tmp_path / "c")


def test_swarm_stacked_checkpoint(tmp_path):
    """Client-stacked pytrees (the swarm state) round-trip too."""
    stacked = {"w": jnp.arange(12.0).reshape(3, 4)}
    save_checkpoint(tmp_path / "swarm", stacked, step=7)
    restored, step = restore_into(jax.tree.map(jnp.zeros_like, stacked),
                                  tmp_path / "swarm")
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(stacked["w"]))
