"""Sweep-engine parity/property harness (PR 3).

Locks down the Table-II method axis: the vmapped ``run_sweep`` program
must reproduce each serial ``run_method`` slice bit-for-bit (same PRNG
keys), the method rows must match the plain static-branch engine
paths, the pooled sampler must cover exactly the real global rows, and
the unified fit key schedule must make ``fit``/``fit_scanned``
bitwise interchangeable.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import OptimizerConfig, SwarmConfig
from repro.core.baselines import (make_method_setup, run_method,
                                  run_sweep_table, sweep_keys)
from repro.core.engine import (EngineConfig, SWEEP_METHODS, jit_run_rounds,
                               jit_run_sweep, make_swarm_data,
                               make_swarm_state, make_sweep_config,
                               make_sweep_state, method_params, run_sweep,
                               sample_local_batch, sample_swarm_batch)
from repro.core.swarm import SwarmTrainer
from repro.data.dr import TABLE_I, make_dr_swarm_data
from repro.models import build_model
from repro.optim.optimizers import make_optimizer

SMALL_TABLE = np.maximum(TABLE_I // 16, (TABLE_I > 0).astype(np.int64) * 2)
N = TABLE_I.shape[1]


@pytest.fixture(scope="module")
def dr_clients():
    return make_dr_swarm_data(image_size=16, seed=0, table=SMALL_TABLE)


@pytest.fixture(scope="module")
def dr_model():
    return build_model(get_config("squeezenet-dr"))


def _swarm(rounds=2, local_steps=2):
    return SwarmConfig(n_clients=N, n_clusters=3, rounds=rounds,
                       local_steps=local_steps, kmeans_iters=10)


OPT = OptimizerConfig(name="adam", lr=2e-3)


def _params_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ----------------------------------------------------- one-program property


def test_sweep_smoke_one_program(dr_clients, dr_model):
    """Fail-fast stage for test.sh: 2 rounds x 4 methods lower to ONE
    executable, run, and produce finite well-formed metrics; repeated
    sweeps hit the jit cache."""
    swarm = _swarm()
    cfg, data = make_method_setup(dr_model, dr_clients, swarm, OPT,
                                  batch_size=8)
    keys = jax.random.split(jax.random.PRNGKey(0), len(SWEEP_METHODS))
    states = make_sweep_state(dr_model, cfg.opt, dr_clients, keys)
    sweep = make_sweep_config(N)

    # one lowering == one device program for the whole 4-method fit
    lowered = jax.jit(run_sweep, static_argnames=("cfg", "rounds")).lower(
        states, data, cfg, sweep, 2)
    compiled = lowered.compile()
    s, ms = compiled(states, data, sweep)

    M, R = len(SWEEP_METHODS), 2
    assert np.asarray(ms.mean_val_acc).shape == (M, R)
    assert np.isfinite(np.asarray(ms.mean_val_acc)).all()
    assert np.isfinite(np.asarray(ms.train_loss)).all()
    assert np.asarray(ms.assignments).shape == (M, R, N)
    assert (np.asarray(s.round) == R).all()

    # module-level entry point: at most one compile, then cache hits
    states = make_sweep_state(dr_model, cfg.opt, dr_clients, keys)
    n0 = jit_run_sweep._cache_size()
    s2, _ = jit_run_sweep(states, data, cfg, sweep, 2)
    n1 = jit_run_sweep._cache_size()
    assert n1 <= n0 + 1
    s2 = jax.tree.map(jnp.copy, s2)
    jit_run_sweep(s2, data, cfg, sweep, 2)
    assert jit_run_sweep._cache_size() == n1, "run_sweep recompiled"


# ------------------------------------------------- sweep vs serial parity


def test_sweep_rows_match_serial_run_method(dr_clients, dr_model):
    """The parity contract: row m of one vmapped run_sweep program ==
    the serial run_method slice seeded with the same key — allclose
    per-round accuracies, bitwise-equal final params (every method is
    deterministic in its key)."""
    swarm = _swarm(rounds=2, local_steps=2)
    cfg, data = make_method_setup(dr_model, dr_clients, swarm, OPT,
                                  batch_size=8)
    key = jax.random.PRNGKey(42)
    accs, sweep_run = run_sweep_table(dr_model, dr_clients, swarm, OPT, key,
                                      batch_size=8, cfg=cfg, data=data)
    keys = sweep_keys(key)
    for i, method in enumerate(SWEEP_METHODS):
        acc, serial = run_method(method, dr_model, dr_clients, swarm, OPT,
                                 keys[i], batch_size=8, cfg=cfg, data=data)
        np.testing.assert_allclose(
            np.asarray(sweep_run.metrics.mean_val_acc[i]),
            np.asarray(serial.metrics.mean_val_acc),
            rtol=1e-6, atol=1e-7, err_msg=method)
        np.testing.assert_allclose(accs[method], acc, rtol=1e-6, atol=1e-7)
        _params_equal(jax.tree.map(lambda x: x[i], sweep_run.state.params),
                      serial.state.params)
        np.testing.assert_array_equal(
            np.asarray(sweep_run.metrics.assignments[i]),
            np.asarray(serial.metrics.assignments), err_msg=method)


def test_method_rows_match_plain_engine_paths(dr_clients, dr_model):
    """Cross-validation against the pre-sweep engine: each masked
    method row reproduces the corresponding static cfg.aggregation
    branch bitwise (local == 'none' identity, fedavg == k=1 global
    cluster, bso-sl == full coordinator with k=n_clusters segments)."""
    opt = make_optimizer(OPT)
    data = make_swarm_data(dr_model.cfg, dr_clients)
    base = dict(model=dr_model, opt=opt, local_steps=2, batch_size=8,
                lr=2e-3, n_clusters=3, kmeans_iters=10)
    for method, agg in [("bso-sl", "bso"), ("local", "none"),
                        ("fedavg", "fedavg")]:
        st = make_swarm_state(dr_model, opt, dr_clients,
                              jax.random.PRNGKey(7))
        s1, m1 = jit_run_rounds(st, data, EngineConfig(aggregation="bso",
                                                       **base),
                                2, method_params(method, N))
        st = make_swarm_state(dr_model, opt, dr_clients,
                              jax.random.PRNGKey(7))
        s2, m2 = jit_run_rounds(st, data, EngineConfig(aggregation=agg,
                                                       **base), 2)
        _params_equal(s1.params, s2.params)
        np.testing.assert_array_equal(np.asarray(m1.mean_val_acc),
                                      np.asarray(m2.mean_val_acc),
                                      err_msg=method)


# ------------------------------------------------------- pooled sampling


def test_pooled_sampler_covers_global_rows_and_no_pads():
    """pool=True draws are uniform over the pooled real rows: every
    global row is reachable from every client slot, pad rows never are,
    and clients draw across client boundaries (the 'merged client').
    Labels encode global row ids, so drawn labels ARE the drawn rows."""
    sizes = [5, 3, 2]
    n_max = max(sizes)
    gid, labels = 0, np.full((len(sizes), n_max), -1, np.int32)
    for i, n in enumerate(sizes):
        labels[i, :n] = np.arange(gid, gid + n)
        gid += n
    train = {"images": jnp.zeros((len(sizes), n_max, 2, 2, 3), jnp.float32),
             "labels": jnp.asarray(labels)}
    train_n = jnp.asarray(sizes, jnp.int32)
    seen = [set() for _ in sizes]
    for s in range(200):
        batch = sample_swarm_batch(jax.random.PRNGKey(s), train, train_n, 4,
                                   jnp.asarray(True))
        got = np.asarray(batch["labels"])
        assert got.min() >= 0, "pooled sampler drew a pad row"
        for i in range(len(sizes)):
            seen[i].update(got[i].tolist())
    for i in range(len(sizes)):
        assert seen[i] == set(range(sum(sizes))), \
            f"client slot {i} cannot reach the whole pool"


def test_unpooled_sampler_matches_sample_local_batch():
    """pool=False is the exact per-client draw (same key, same randint)
    — non-centralized sweep rows sample bitwise-identical batches to
    the plain engine path."""
    sizes = [6, 2, 4]
    n_max = max(sizes)
    labels = np.stack([np.where(np.arange(n_max) < n, np.arange(n_max), -1)
                       for n in sizes]).astype(np.int32)
    train = {"images": jnp.zeros((3, n_max, 2, 2, 3), jnp.float32),
             "labels": jnp.asarray(labels)}
    train_n = jnp.asarray(sizes, jnp.int32)
    for s in range(20):
        a = sample_swarm_batch(jax.random.PRNGKey(s), train, train_n, 5,
                               jnp.asarray(False))
        b = sample_local_batch(jax.random.PRNGKey(s), train, train_n, 5)
        np.testing.assert_array_equal(np.asarray(a["labels"]),
                                      np.asarray(b["labels"]))


# --------------------------------------------------- fit key unification


def test_fit_matches_fit_scanned_bitwise(dr_clients, dr_model):
    """One key schedule for both fit paths: the caller's key seeds the
    engine chain once and each round derives its keys in-program, so
    the host loop and the scanned program are bitwise interchangeable."""
    swarm = _swarm(rounds=3, local_steps=2)

    def mk():
        return SwarmTrainer(dr_model, dr_clients, swarm, OPT,
                            jax.random.PRNGKey(5), batch_size=8,
                            aggregation="bso")

    a, b = mk(), mk()
    a.fit(jax.random.PRNGKey(9))
    b.fit_scanned(jax.random.PRNGKey(9))
    assert [l.mean_val_acc for l in a.history] == \
        [l.mean_val_acc for l in b.history]
    assert [l.train_loss for l in a.history] == \
        [l.train_loss for l in b.history]
    for la, lb in zip(a.history, b.history):
        np.testing.assert_array_equal(la.assignments, lb.assignments)
        np.testing.assert_array_equal(la.centers, lb.centers)
        assert la.events == lb.events
    _params_equal(a.params, b.params)
    _params_equal(a.opt_state, b.opt_state)
