"""Hierarchical two-tier coordination tests (PR 9).

Pins the acceptance seams of the O(pods) coordinator:

* weighted/centroid-input k-means — ``weights=None`` bitwise, the
  duplication oracle, zero-weight rows barred from seeding,
* engine path — ``pods == 1`` routes to the flat coordinator BITWISE,
  a hier fit is ONE ``jit_run_rounds`` program, the dropout=0 churn
  composition is bitwise the churn-free hier fit, hier-vs-flat val
  trajectories agree at small N, and the validation errors are
  actionable,
* fleet path — the driver pulls only O(pods * k_local) summaries with
  exactly ONE compiled round step, composes with ``FleetFaults``
  (quorum re-applies the previous pod-cluster map), and the GSPMD
  surface matches shard_map on the trivial mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import OptimizerConfig
from repro.core.engine import (EngineConfig, churn_params, hier_params,
                               jit_run_rounds, make_swarm_data,
                               make_swarm_state, method_params)
from repro.core.kmeans import kmeans, kmeans_pp_init, lloyd_step
from repro.data.dr import TABLE_I, make_dr_swarm_data
from repro.launch.fleet_driver import (FleetFaults, host_hier_coordinator,
                                       run_fleet)
from repro.launch.mesh import make_fleet_mesh
from repro.launch.swarm_fleet import fleet_setup
from repro.models import build_model
from repro.optim.optimizers import make_optimizer
from repro.sharding import use_sharding

N_CLIENTS = 14
SMALL_TABLE = np.maximum(TABLE_I // 16,
                         (TABLE_I > 0).astype(np.int64) * 2)[:, :N_CLIENTS]


@pytest.fixture(scope="module")
def dr_clients():
    return make_dr_swarm_data(image_size=16, seed=0, table=SMALL_TABLE)


@pytest.fixture(scope="module")
def dr_model():
    return build_model(get_config("squeezenet-dr"))


def _pieces(model, clients, *, local_steps=2, key=0):
    opt = make_optimizer(OptimizerConfig(name="adam", lr=2e-3))
    cfg = EngineConfig(model=model, opt=opt, local_steps=local_steps,
                       batch_size=8, lr=2e-3, aggregation="bso",
                       n_clusters=3, p1=0.9, p2=0.8, kmeans_iters=10)
    data = make_swarm_data(model.cfg, clients)
    state = make_swarm_state(model, opt, clients, jax.random.PRNGKey(key))
    return state, data, cfg


def _tree_equal(a, b):
    return all(bool(np.array_equal(np.asarray(x), np.asarray(y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ------------------------------------------------------- weighted k-means


def test_kmeans_unit_weights_bitwise_unweighted():
    """weights=ones is the unweighted run bit-for-bit: the first-seed
    remap is the identity, ``d * 1.0`` is exact, and the 1e-9
    denominator floor only differs on empty clusters, whose means the
    reseed overwrites either way."""
    key = jax.random.PRNGKey(3)
    X = jax.random.normal(jax.random.PRNGKey(7), (40, 6))
    C0, a0 = kmeans(key, X, k=4, iters=8)
    C1, a1 = kmeans(key, X, k=4, iters=8, weights=jnp.ones(40))
    np.testing.assert_array_equal(np.asarray(C0), np.asarray(C1))
    np.testing.assert_array_equal(np.asarray(a0), np.asarray(a1))


def test_lloyd_step_weighted_matches_duplication_oracle():
    """Integer weights == physically duplicated rows: one weighted
    Lloyd step from a fixed centroid set must produce the duplicated
    run's centroids (the centroid-input mode's defining property)."""
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(12, 5)), jnp.float32)
    w = jnp.asarray(rng.integers(1, 4, size=12), jnp.float32)
    C = X[:3] + 0.01  # every cluster non-empty, no reseed ties
    X_dup = jnp.repeat(X, np.asarray(w, np.int64), axis=0)
    C_w = lloyd_step(X, C, 3, weights=w)
    C_dup = lloyd_step(X_dup, C, 3)
    np.testing.assert_allclose(np.asarray(C_w), np.asarray(C_dup),
                               rtol=1e-5, atol=1e-6)


def test_kmeans_pp_init_zero_weight_rows_never_seed():
    """Zero-weight rows (empty pod-clusters) must anchor nothing: every
    ++ seed is drawn from the positive-weight rows, even when the
    zero-weight rows are extreme outliers that unweighted ++ seeding
    would certainly pick."""
    rng = np.random.default_rng(1)
    X = np.asarray(rng.normal(size=(20, 4)), np.float32)
    X[10:] += 1000.0  # far outliers
    w = jnp.asarray([1.0] * 10 + [0.0] * 10)
    for s in range(5):
        C0 = np.asarray(kmeans_pp_init(jax.random.PRNGKey(s),
                                       jnp.asarray(X), 4, weights=w))
        for row in C0:
            dists = np.abs(X[:10] - row[None, :]).sum(axis=1)
            assert dists.min() < 1e-6, (s, row)


# ------------------------------------------------------------ engine path


def test_hier_pods1_bitwise_equals_flat(dr_model, dr_clients):
    """One pod = the whole swarm: the degenerate two-tier program IS
    the flat coordinator, bit for bit (params, metrics, key stream)."""
    rounds = 2
    state, data, cfg = _pieces(dr_model, dr_clients)
    s_flat, m_flat = jit_run_rounds(state, data, cfg, rounds)
    state, data, cfg = _pieces(dr_model, dr_clients)
    s_p1, m_p1 = jit_run_rounds(state, data, cfg, rounds,
                                hier=hier_params(N_CLIENTS, 1))
    assert _tree_equal(s_flat.params, s_p1.params)
    np.testing.assert_array_equal(np.asarray(m_flat.mean_val_acc),
                                  np.asarray(m_p1.mean_val_acc))
    np.testing.assert_array_equal(np.asarray(s_flat.key),
                                  np.asarray(s_p1.key))


def test_hier_fit_is_one_program_and_learns(dr_model, dr_clients):
    """A multi-pod hier fit is ONE jit_run_rounds executable (never one
    per round), re-running the same HierParams value hits the cache,
    and the trajectory stays near the flat oracle at small N (same
    protocol, different coordinator granularity — statistical, not
    bitwise)."""
    rounds, hp = 3, hier_params(N_CLIENTS, 4, k_local=2)
    n0 = jit_run_rounds._cache_size()
    state, data, cfg = _pieces(dr_model, dr_clients, local_steps=4)
    _, m_hier = jit_run_rounds(state, data, cfg, rounds, hier=hp)
    assert jit_run_rounds._cache_size() == n0 + 1
    state, data, cfg = _pieces(dr_model, dr_clients, local_steps=4, key=1)
    _, _ = jit_run_rounds(state, data, cfg, rounds,
                          hier=hier_params(N_CLIENTS, 4, k_local=2))
    assert jit_run_rounds._cache_size() == n0 + 1  # equal static value

    state, data, cfg = _pieces(dr_model, dr_clients, local_steps=4)
    _, m_flat = jit_run_rounds(state, data, cfg, rounds)
    hier_acc = float(np.asarray(m_hier.mean_val_acc)[-1])
    flat_acc = float(np.asarray(m_flat.mean_val_acc)[-1])
    assert 0.0 <= hier_acc <= 1.0
    assert abs(hier_acc - flat_acc) < 0.25, (hier_acc, flat_acc)


def test_hier_churn_dropout0_bitwise_and_composition(dr_model, dr_clients):
    """Churn composes with the two-tier coordinator: dropout=0 churn is
    BITWISE the churn-free hier fit (masks are float identities, keys
    consumed unconditionally), and dropout>0 still runs/learns — the
    present mask feeds the pod k-means as its member mask."""
    rounds, hp = 2, hier_params(N_CLIENTS, 4, k_local=2)
    state, data, cfg = _pieces(dr_model, dr_clients)
    s_ref, m_ref = jit_run_rounds(state, data, cfg, rounds, hier=hp)
    state, data, cfg = _pieces(dr_model, dr_clients)
    s_0, m_0 = jit_run_rounds(state, data, cfg, rounds,
                              churn=churn_params(dropout=0.0), hier=hp)
    assert _tree_equal(s_ref.params, s_0.params)
    np.testing.assert_array_equal(np.asarray(m_ref.mean_val_acc),
                                  np.asarray(m_0.mean_val_acc))

    state, data, cfg = _pieces(dr_model, dr_clients)
    _, m_d = jit_run_rounds(state, data, cfg, rounds,
                            churn=churn_params(dropout=0.4,
                                               stale_decay=0.5), hier=hp)
    present = np.asarray(m_d.present)
    assert present.shape == (rounds, N_CLIENTS)
    assert 0 < present.mean() < 1
    assert np.all(np.isfinite(np.asarray(m_d.mean_val_acc)))


def test_hier_validation_errors(dr_model, dr_clients):
    """The seams refuse loudly: hier + method axis, non-bso
    aggregation, bad pod partitions and oversize k_local all raise
    with actionable messages."""
    state, data, cfg = _pieces(dr_model, dr_clients)
    with pytest.raises(ValueError, match="plain path only"):
        jit_run_rounds(state, data, cfg, 1,
                       method=method_params("fedavg", N_CLIENTS),
                       hier=hier_params(N_CLIENTS, 4))
    state, data, cfg = _pieces(dr_model, dr_clients, local_steps=2)
    import dataclasses
    cfg_fed = dataclasses.replace(cfg, aggregation="fedavg")
    with pytest.raises(ValueError, match="aggregation='bso'"):
        jit_run_rounds(state, data, cfg_fed, 1,
                       hier=hier_params(N_CLIENTS, 4))
    with pytest.raises(ValueError, match="partition"):
        hier_params(N_CLIENTS, 0, pods=((0, 1), (1, 2)))
    with pytest.raises(ValueError, match="smallest pod"):
        hier_params(N_CLIENTS, 7, k_local=3)  # smallest pod = 2
    state, data, cfg = _pieces(dr_model, dr_clients)
    with pytest.raises(ValueError, match="swarm has"):
        jit_run_rounds(state, data, cfg, 1,
                       hier=hier_params(N_CLIENTS - 2, 4))


# ------------------------------------------------------------- fleet path


N_FLEET = 8
FLEET_TABLE = np.maximum(TABLE_I // 16,
                         (TABLE_I > 0).astype(np.int64) * 2)[:, :N_FLEET]


@pytest.fixture(scope="module")
def fleet_clients():
    return make_dr_swarm_data(image_size=16, seed=0, table=FLEET_TABLE)


def _opt():
    return make_optimizer(OptimizerConfig(name="adam", lr=2e-3))


def test_fleet_hier_driver_one_program_o_pods_upload(dr_model,
                                                     fleet_clients):
    """The hier driver: ONE compiled round step, only O(pods * k_local)
    summary rows pulled per round (never the (N, F) stat matrix), the
    comm ledger's measured-vs-flat reduction, and the coordinator loop
    actually closing (round r+1 applies round r's pod-cluster map)."""
    mesh = make_fleet_mesh(N_FLEET)
    kl = 2
    S = mesh.shape["pod"] * kl
    res = run_fleet(dr_model, _opt(), mesh, fleet_clients, rounds=3,
                    local_steps=2, batch_size=8, seed=0,
                    n_clusters=min(3, S), hier_k_local=kl)
    assert res.n_compiles == 1
    assert len(res.history) == 3
    assert res.meta["hier"] == {"k_local": kl,
                                "n_pods": mesh.shape["pod"],
                                "summary_rows": S}
    for log in res.history:
        assert log.stats.shape[0] == S            # summaries, not clients
        assert log.val_acc.shape == (S,)
        assert log.assignments.shape == (S,)      # the pod-cluster map g
        assert 0.0 <= log.mean_val_acc <= 1.0
        assert np.isfinite(log.train_loss)
    # loop closure: the g decided from round r's summaries is the g
    # operand of round r+1 (round 0 rides the singleton fallback)
    np.testing.assert_array_equal(res.history[1].applied_clusters,
                                  res.history[0].assignments)
    np.testing.assert_array_equal(res.history[2].applied_clusters,
                                  res.history[1].assignments)
    # the ledger: O(pods) summaries beat the flat O(clients) upload
    assert res.comm["summary_rows"] == S
    assert res.comm["summary_upload_bytes"] \
        < res.comm["flat_upload_bytes"]
    # determinism: replaying the global tier from a round's pulled
    # summaries reproduces its pod-cluster map bit-for-bit
    for r, log in enumerate(res.history):
        assert log.counts.shape == (S,) and log.valsums.shape == (S,)
        np.testing.assert_allclose(log.counts.sum(), N_FLEET, rtol=1e-6)
        g2, c2, _ = host_hier_coordinator(
            log.stats, log.counts, log.valsums, k=min(3, S), p1=0.9,
            p2=0.8, kmeans_iters=20, seed=0, round_idx=r)
        np.testing.assert_array_equal(g2, log.assignments)
        np.testing.assert_array_equal(c2, log.centers)


def test_fleet_hier_with_faults_quorum(dr_model, fleet_clients):
    """FleetFaults composes with the hier driver: still ONE program,
    quorum misses re-apply the previous pod-cluster map, and the
    summary counts reflect the in-program report mask (a straggler
    trains but never reaches the pod k-means)."""
    mesh = make_fleet_mesh(N_FLEET)
    kl = 2
    S = mesh.shape["pod"] * kl
    faults = FleetFaults(drop_rate=0.3, straggler_rate=0.2,
                         stale_decay=0.5, quorum=4)
    res = run_fleet(dr_model, _opt(), mesh, fleet_clients, rounds=4,
                    local_steps=2, batch_size=8, seed=0,
                    n_clusters=min(3, S), faults=faults, hier_k_local=kl)
    assert res.n_compiles == 1
    prev_g = np.zeros(S, np.int32)
    for log in res.history:
        assert log.present is not None and log.reported is not None
        if not log.coordinated:
            np.testing.assert_array_equal(log.assignments, prev_g)
            assert "quorum miss" in log.events[0]
        prev_g = log.assignments
        assert 0.0 <= log.mean_val_acc <= 1.0


def test_fleet_hier_validations(dr_model, fleet_clients):
    mesh = make_fleet_mesh(N_FLEET)
    with pytest.raises(ValueError, match="exclusive"):
        run_fleet(dr_model, _opt(), mesh, fleet_clients, rounds=1,
                  hier_k_local=2, eval_buckets=2)
    S = mesh.shape["pod"] * 1
    with pytest.raises(ValueError, match="raise hier_k_local"):
        run_fleet(dr_model, _opt(), mesh, fleet_clients, rounds=1,
                  hier_k_local=1, n_clusters=S + 1)


def test_fleet_hier_gspmd_matches_shard_map_trivial_mesh(dr_model,
                                                         fleet_clients):
    """The two hier partitioning surfaces run the same math: on the
    trivial mesh (where GSPMD can serve the vmapped conv) one round
    with identical inputs produces matching summaries and params
    (allclose — different collective lowerings reorder reductions)."""
    mesh = make_fleet_mesh(N_FLEET)
    if mesh.shape["pod"] != 1:
        pytest.skip("trivial-mesh parity check (GSPMD cannot partition "
                    "the vmapped conv over pods)")
    opt = _opt()
    kl, S = 2, 2
    outs = []
    for spmd in ("shard_map", "auto"):
        prog = fleet_setup(dr_model, opt, mesh, k=N_FLEET,
                           n_local_steps=2, spmd=spmd, hier_k_local=kl)
        in_sh = prog.in_shardings
        with mesh, use_sharding(mesh, prog.rules):
            keys = jax.random.split(jax.random.PRNGKey(0), N_FLEET)
            sparams = jax.device_put(jax.vmap(dr_model.init)(keys),
                                     in_sh[0])
            sopt = jax.device_put(jax.vmap(opt.init)(sparams), in_sh[1])
            from repro.core.engine import stack_eval_split
            from repro.launch.fleet_driver import _sample_round_batch
            batch = jax.device_put(
                _sample_round_batch(dr_model.cfg, fleet_clients, 16,
                                    seed=0, round_idx=0), in_sh[2])
            val = jax.device_put(
                stack_eval_split(dr_model.cfg, fleet_clients, "val"),
                in_sh[3])
            args = (sparams, sopt, batch, val,
                    jax.device_put(jnp.float32(2e-3), in_sh[4]),
                    jax.device_put(jnp.zeros(S, jnp.int32), in_sh[5]),
                    jax.device_put(jnp.asarray(False), in_sh[6]),
                    jax.device_put(jnp.arange(N_FLEET, dtype=jnp.int32),
                                   in_sh[7]),
                    jax.device_put(jnp.zeros(N_FLEET, jnp.int32),
                                   in_sh[8]),
                    jax.device_put(jax.random.PRNGKey(9), in_sh[9]),
                    jax.device_put(jnp.ones(N_FLEET, jnp.float32),
                                   in_sh[10]))
            p2, _, out = prog.jit_fn(*args)
            outs.append((p2, out))
    (pa, oa), (pb, ob) = outs
    for x, y in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(oa.centroids),
                               np.asarray(ob.centroids), rtol=1e-6,
                               atol=1e-6)
    np.testing.assert_array_equal(np.asarray(oa.a_local),
                                  np.asarray(ob.a_local))
    np.testing.assert_allclose(np.asarray(oa.counts),
                               np.asarray(ob.counts), rtol=1e-6)
