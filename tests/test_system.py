"""End-to-end behaviour tests for the BSO-SL system (paper §III/§IV at
reduced scale): the full protocol runs, improves over initialization,
collaboration beats isolation, and the model-agnostic claim holds."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import OptimizerConfig, SwarmConfig
from repro.core.swarm import SwarmTrainer
from repro.data.dr import TABLE_I, make_dr_swarm_data
from repro.data.tokens import make_token_swarm_data
from repro.models import build_model

SMALL_TABLE = np.maximum(TABLE_I // 16, (TABLE_I > 0).astype(np.int64) * 2)


@pytest.fixture(scope="module")
def dr_clients():
    return make_dr_swarm_data(image_size=16, seed=0, table=SMALL_TABLE)


def _trainer(model, clients, aggregation, rounds=2, local_steps=4, seed=0):
    swarm = SwarmConfig(n_clients=len(clients), n_clusters=3, rounds=rounds,
                        local_steps=local_steps)
    return SwarmTrainer(model, clients, swarm,
                        OptimizerConfig(name="adam", lr=2e-3),
                        jax.random.PRNGKey(seed), batch_size=8,
                        aggregation=aggregation)


@pytest.mark.parametrize("fit_keys", [
    (1, 11, 21),
    pytest.param((31, 41, 51), marks=pytest.mark.slow),
    pytest.param((61, 71, 81), marks=pytest.mark.slow),
])
def test_bso_swarm_round_runs_and_improves(dr_clients, fit_keys):
    """The protocol runs end-to-end and learns. With ~16x-reduced data
    the per-clinic test sets are 2-3 samples, so accuracy is quantised
    and a single fit key is roulette (one sample flip moves Eq. 3 by
    ~0.02); the robust signals are (a) train loss descends across
    rounds, (b) final mean accuracy clears the 5-class random floor
    *averaged over fit keys* (same reformulation as
    test_collaboration_beats_isolation), and (c) the per-round
    protocol artifacts are well-formed. Tier-1 averages the pinned key
    triple; the slow triples (nightly ``--runslow``) replicate the
    statistic on fresh keys. The full-scale Table II comparison lives
    in benchmarks/table2_methods."""
    model = build_model(get_config("squeezenet-dr"))
    accs = []
    for i, fit_key in enumerate(fit_keys):
        tr = _trainer(model, dr_clients, "bso", rounds=4, local_steps=10)
        tr.fit(jax.random.PRNGKey(fit_key))
        accs.append(tr.mean_accuracy("test"))
        if i == 0:
            losses = [log.train_loss for log in tr.history]
            # every round's training loss sits below the ln(5)=1.61
            # random floor (per-round loss is non-monotone by design:
            # aggregation mixes cluster models and the next round
            # re-descends)
            assert all(l < 1.61 for l in losses), losses
            for log in tr.history:
                assert log.assignments.shape == (14,)
                assert set(log.assignments.tolist()) <= {0, 1, 2}
                assert log.centers.shape[0] == 3
    assert float(np.mean(accs)) > 0.25, accs   # above 1/5 random


@pytest.mark.parametrize("fit_keys", [
    (3, 13, 23),
    pytest.param((33, 43, 53), marks=pytest.mark.slow),
    pytest.param((63, 73, 83), marks=pytest.mark.slow),
])
def test_collaboration_beats_isolation(dr_clients, fit_keys):
    """BSO-SL must not collapse relative to isolated local training.

    At this reduced scale the per-client Eq. 3 protocol rewards local
    overfitting of the tiny clinics (see the table2 ordering notes), so
    single-key margins are key-roulette: average over several fit keys
    and allow the documented local-advantage gap — the guard is
    'aggregation still trains' (floor) and 'no catastrophic collapse'
    (bounded gap), not 'bso wins'. Tier-1 averages the pinned triple;
    the slow triples (nightly ``--runslow``) replicate the statistic."""
    model = build_model(get_config("squeezenet-dr"))
    runs = {}
    for agg in ("none", "bso"):
        accs = []
        for fit_key in fit_keys:
            tr = _trainer(model, dr_clients, agg, rounds=4, local_steps=10,
                          seed=2)
            tr.fit(jax.random.PRNGKey(fit_key))
            accs.append(tr.mean_accuracy("test"))
        runs[agg] = float(np.mean(accs))
    assert runs["bso"] >= runs["none"] - 0.20, runs
    assert all(a > 0.15 for a in runs.values()), runs


def test_swarm_is_model_agnostic_lm():
    """RQ2 structurally: the same SwarmTrainer drives an LM family."""
    cfg = get_config("granite-3-2b").smoke()
    clients = make_token_swarm_data(6, cfg.vocab_size, n_seqs=12, seq_len=32)
    model = build_model(cfg)
    swarm = SwarmConfig(n_clients=6, n_clusters=2, rounds=2, local_steps=4)
    tr = SwarmTrainer(model, clients, swarm,
                      OptimizerConfig(name="adam", lr=2e-3),
                      jax.random.PRNGKey(0), batch_size=4, aggregation="bso")
    tr.fit(jax.random.PRNGKey(1))
    assert len(tr.history) == 2
    assert np.isfinite(tr.mean_accuracy("test"))


def test_fedavg_differs_from_bso_assignments(dr_clients):
    """FedAvg aggregates globally (one cluster); BSO-SL clusters into
    k=3 — the mechanisms must be observably different."""
    model = build_model(get_config("squeezenet-dr"))
    fa = _trainer(model, dr_clients, "fedavg", rounds=1, local_steps=2)
    fa.fit(jax.random.PRNGKey(4))
    bs = _trainer(model, dr_clients, "bso", rounds=1, local_steps=2)
    bs.fit(jax.random.PRNGKey(4))
    assert set(fa.history[0].assignments.tolist()) == {0}
    assert len(set(bs.history[0].assignments.tolist())) >= 2


def test_fedavg_synchronizes_clients(dr_clients):
    """After a FedAvg round every client holds identical parameters."""
    model = build_model(get_config("squeezenet-dr"))
    tr = _trainer(model, dr_clients, "fedavg", rounds=1, local_steps=2)
    tr.fit(jax.random.PRNGKey(5))
    leaf = jax.tree.leaves(tr.params)[0]
    first = np.asarray(leaf[0])
    for i in range(1, leaf.shape[0]):
        np.testing.assert_allclose(np.asarray(leaf[i]), first, rtol=1e-5,
                                   atol=1e-6)


def test_bso_cluster_members_synchronized(dr_clients):
    """After BSA, clients in the same (post-swap) cluster share params."""
    model = build_model(get_config("squeezenet-dr"))
    tr = _trainer(model, dr_clients, "bso", rounds=1, local_steps=2)
    tr.fit(jax.random.PRNGKey(6))
    a = tr.history[-1].assignments
    leaf = jax.tree.leaves(tr.params)[0]
    for c in set(a.tolist()):
        members = np.where(a == c)[0]
        ref = np.asarray(leaf[members[0]])
        for m in members[1:]:
            np.testing.assert_allclose(np.asarray(leaf[m]), ref, rtol=1e-5,
                                       atol=1e-6)


def test_vmapped_eval_matches_per_client_loop(dr_clients):
    """New-vs-old parity: the one-program vmapped client eval equals the
    old per-client, per-batch eval_client host loop on every split."""
    from repro.core.swarm import eval_client
    from repro.utils.tree import tree_index
    model = build_model(get_config("squeezenet-dr"))
    tr = _trainer(model, dr_clients, "bso", rounds=1, local_steps=2)
    tr.fit(jax.random.PRNGKey(7))
    for split in ("val", "test"):
        scores = tr.client_scores(split)
        for i, c in enumerate(tr.data):
            X, y = c[split]
            old = eval_client(tr._eval, tr.cfg, tree_index(tr.params, i), X, y)
            np.testing.assert_allclose(scores[i], old, rtol=1e-5, atol=1e-6)


def test_centralized_baseline_runs(dr_clients):
    from repro.core.baselines import train_centralized
    model = build_model(get_config("squeezenet-dr"))
    _, acc = train_centralized(model, dr_clients,
                               OptimizerConfig(name="adam", lr=2e-3),
                               jax.random.PRNGKey(0), steps=30, batch_size=16)
    assert 0.0 <= acc <= 1.0
