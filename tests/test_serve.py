"""Serving subsystem tests (PR 7): scheduler invariants, the
continuous-batching engine's per-bucket program budget, flash_decode
parity inside full multi-token generations (ring-buffer and
non-multiple-of-block_k cases included), and the train-to-serve bridge
(fleet checkpoint -> repro.serve load -> generation / classification).

Runs on whatever backend pytest sees (the Pallas paths take interpret
mode on CPU).
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import serve
from repro.configs import get_config
from repro.models import build_model
from repro.serve import (BucketSpec, ImageClassifier, Request, ServeEngine,
                         SlotScheduler, default_bucket_layout)

# ----------------------------------------------------------------- scheduler


def _req(rid, plen, new=4):
    return Request(rid=rid, prompt=np.zeros(plen, np.int32),
                   max_new_tokens=new)


def test_bucket_routing_smallest_fit():
    s = SlotScheduler((BucketSpec(2, 16), BucketSpec(2, 64)))
    assert s.bucket_for(_req(0, 4)) == 0          # 4+4 fits 16
    assert s.bucket_for(_req(1, 13)) == 1         # 13+4 spills to 64
    assert s.bucket_for(_req(2, 60, new=8)) is None
    with pytest.raises(ValueError):
        s.submit(_req(3, 100))


def test_admission_fifo_per_bucket_no_cross_blocking():
    s = SlotScheduler((BucketSpec(1, 16), BucketSpec(1, 64)))
    for rid, plen in ((0, 4), (1, 4), (2, 30), (3, 4)):
        s.submit(_req(rid, plen))
    adm = s.admit()
    # bucket 0 takes rid 0 (FIFO); rid 2 is NOT blocked behind rid 1
    assert [(r.rid) for _, r in adm[0]] == [0]
    assert [(r.rid) for _, r in adm[1]] == [2]
    assert [r.rid for r in s.queue] == [1, 3]
    assert s.admit() == {}                        # both buckets full
    s.release(0, adm[0][0][0])
    adm2 = s.admit()
    assert [r.rid for _, r in adm2[0]] == [1]     # queue order kept
    assert s.occupancy()["b1xs16"] == 1.0


def test_no_spill_to_larger_bucket():
    s = SlotScheduler((BucketSpec(1, 16), BucketSpec(1, 64)))
    s.submit(_req(0, 4))
    s.submit(_req(1, 4))
    s.admit()
    # bucket 1 idle, but the small request must wait for bucket 0
    assert s.occupancy()["b1xs64"] == 0.0
    assert [r.rid for r in s.queue] == [1]


def test_default_bucket_layout_pow2():
    bs = default_bucket_layout(128, slots=8, n_buckets=2)
    assert [(b.batch, b.seq) for b in bs] == [(4, 64), (4, 128)]


# -------------------------------------------------------------------- engine


@pytest.fixture(scope="module")
def lm_model():
    return build_model(get_config("granite-3-2b").smoke())


@pytest.fixture(scope="module")
def lm_params(lm_model):
    return lm_model.init(jax.random.PRNGKey(0))


def _prompts(vocab, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=n) for n in lens]


BUCKETS = (BucketSpec(batch=2, seq=16), BucketSpec(batch=2, seq=48))


def test_engine_smoke_program_budget(lm_model, lm_params):
    """More requests than slots -> continuous admission, every request
    drains, and the steady-state compile census is exactly 1 prefill +
    1 decode executable per bucket (the zero-retrace property)."""
    prompts = _prompts(lm_model.cfg.vocab_size, (3, 7, 12, 25, 5, 18))
    res, eng = serve.generate(lm_model, lm_params, prompts,
                              max_new_tokens=6, buckets=BUCKETS,
                              return_engine=True)
    assert [len(r.tokens) for r in res] == [6] * 6
    assert {r.bucket for r in res} == {"b2xs16", "b2xs48"}
    assert eng.n_prefill_calls > 2        # > one admission wave per bucket
    cc = eng.compile_counts()
    assert cc == {"b2xs16": {"prefill": 1, "decode": 1},
                  "b2xs48": {"prefill": 1, "decode": 1}}
    assert all(r.t_done >= r.t_first >= r.t_submit > 0 for r in res)


def test_engine_matches_per_token_reference(lm_model, lm_params):
    """The bucketed engine (chunked prefill + per-row-pos decode over a
    shared slot pool) reproduces the naive one-request-at-a-time
    teacher-forced loop token for token."""
    prompts = _prompts(lm_model.cfg.vocab_size, (3, 9, 14), seed=1)
    res = serve.generate(lm_model, lm_params, prompts, max_new_tokens=5,
                         buckets=BUCKETS)

    def ref_generate(prompt, max_new, S):
        cache = lm_model.init_cache(1, S)
        tok = None
        for t, p in enumerate(prompt):
            logits, cache = lm_model.decode_step(
                lm_params, jnp.asarray([[p]], jnp.int32), cache,
                jnp.int32(t))
            tok = int(jnp.argmax(logits[0, -1]))
        out = [tok]
        pos = len(prompt)
        while len(out) < max_new:
            logits, cache = lm_model.decode_step(
                lm_params, jnp.asarray([[out[-1]]], jnp.int32), cache,
                jnp.int32(pos))
            out.append(int(jnp.argmax(logits[0, -1])))
            pos += 1
        return out

    for r, p in zip(res, prompts):
        S = 16 if len(p) + 5 <= 16 else 48
        assert r.tokens == ref_generate(p, 5, S)


def test_pallas_parity_full_generation(lm_model, lm_params):
    """flash_decode on the engine's hot path vs the jnp path, inside a
    full multi-token generation. Bucket ceilings 16/48 are NOT
    multiples of the kernel's block_k — the tile-padding path is what
    production bucket layouts hit."""
    prompts = _prompts(lm_model.cfg.vocab_size, (3, 12, 25, 18), seed=2)
    res = serve.generate(lm_model, lm_params, prompts, max_new_tokens=6,
                         buckets=BUCKETS)
    model_p = build_model(dataclasses.replace(lm_model.cfg, use_pallas=True))
    res_p = serve.generate(model_p, lm_params, prompts, max_new_tokens=6,
                           buckets=BUCKETS)
    for a, b in zip(res, res_p):
        assert a.tokens == b.tokens


def test_pallas_parity_ring_buffer_generation(lm_model):
    """Sliding-window ring-buffer cache: generation runs past the
    window so the ring wraps; kernel and jnp paths must still agree."""
    cfg = dataclasses.replace(lm_model.cfg, sliding_window=12,
                              cache_ring=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _prompts(cfg.vocab_size, (4, 9), seed=3)
    kw = dict(max_new_tokens=10, buckets=(BucketSpec(2, 32),))
    res = serve.generate(model, params, prompts, **kw)
    model_p = build_model(dataclasses.replace(cfg, use_pallas=True))
    res_p = serve.generate(model_p, params, prompts, **kw)
    for a, b in zip(res, res_p):
        assert len(a.tokens) == 10 and a.tokens == b.tokens


def test_chunked_prefill_matches_single_chunk(lm_model, lm_params):
    prompts = _prompts(lm_model.cfg.vocab_size, (3, 12, 25), seed=4)
    res = serve.generate(lm_model, lm_params, prompts, max_new_tokens=4,
                         buckets=BUCKETS)
    res_c = serve.generate(lm_model, lm_params, prompts, max_new_tokens=4,
                           buckets=BUCKETS, prefill_chunk=8)
    for a, b in zip(res, res_c):
        assert a.tokens == b.tokens


def test_eos_early_stop(lm_model, lm_params):
    prompts = _prompts(lm_model.cfg.vocab_size, (3, 7), seed=0)
    res = serve.generate(lm_model, lm_params, prompts, max_new_tokens=6,
                         buckets=BUCKETS)
    eos = res[0].tokens[1]
    res_e = serve.generate(lm_model, lm_params, prompts, max_new_tokens=6,
                           eos_id=eos, buckets=BUCKETS)
    # greedy decode is deterministic: output is the unconstrained stream
    # truncated at (and including) the first eos occurrence
    cut = res[0].tokens.index(eos) + 1
    assert res_e[0].tokens == res[0].tokens[:cut]


def test_engine_rejects_family_without_prefill():
    model = build_model(get_config("mamba2-370m").smoke())
    with pytest.raises(ValueError, match="chunked-prefill|ssm"):
        ServeEngine(model, None, (BucketSpec(1, 16),))


# -------------------------------------------------- train-to-serve bridge


def test_fleet_ckpt_to_serve_cnn(tmp_path):
    """run_fleet -> --ckpt export -> serve load -> batched scoring; the
    served labels equal a direct forward on the reduced params."""
    from repro.launch.fleet_driver import make_unit_fleet, run_fleet
    model, opt, mesh, clients = make_unit_fleet(4, image_size=16,
                                                data_scale=16)
    p = os.fspath(tmp_path / "fleet")
    res = run_fleet(model, opt, mesh, clients, rounds=1, local_steps=2,
                    batch_size=4, n_clusters=2, ckpt_path=p)
    assert os.path.exists(p + ".npz") and os.path.exists(p + ".json")

    m2, params = serve.load_checkpoint(p)
    assert m2.cfg == model.cfg            # manifest round-trips the config
    assert m2 is model                    # build_model cache hit
    imgs = [np.asarray(clients[0]["train"][0][i]) for i in range(5)]
    out = serve.classify(m2, params, imgs, batch_buckets=(1, 4))
    direct = np.argmax(np.asarray(
        m2.forward(params, {"images": jnp.asarray(np.stack(imgs))})[0]), -1)
    assert [o.label for o in out] == direct.tolist()

    # per-client reduction serves one cluster's model verbatim
    _, p0 = serve.load_checkpoint(p, client="client:0")
    sp = np.asarray(jax.tree.leaves(res.params)[0])
    np.testing.assert_array_equal(np.asarray(jax.tree.leaves(p0)[0]).shape,
                                  sp[0].shape)


def test_fleet_ckpt_to_serve_lm_e2e(tmp_path):
    """The ISSUE acceptance path: an LM swarm through run_fleet ->
    checkpoint -> repro.serve load -> autoregressive generation, with
    the use_pallas decode path matching the jnp ref path."""
    from repro.data.tokens import make_token_swarm_data
    from repro.launch.fleet_driver import run_fleet
    from repro.launch.mesh import make_fleet_mesh
    from repro.configs.base import OptimizerConfig
    from repro.optim.optimizers import make_optimizer

    model = build_model(get_config("granite-3-2b").smoke())
    clients = make_token_swarm_data(4, model.cfg.vocab_size, n_seqs=8,
                                    seq_len=16)
    opt = make_optimizer(OptimizerConfig(name="adam", lr=1e-3))
    p = os.fspath(tmp_path / "lm_fleet")
    run_fleet(model, opt, make_fleet_mesh(4), clients, rounds=1,
              local_steps=2, batch_size=4, n_clusters=2, eval_batch=2,
              ckpt_path=p)

    m_jnp, params = serve.load_checkpoint(p, use_pallas=False)
    prompts = _prompts(m_jnp.cfg.vocab_size, (3, 8), seed=5)
    kw = dict(max_new_tokens=5, buckets=(BucketSpec(2, 16),))
    res = serve.generate(m_jnp, params, prompts, **kw)
    assert all(len(r.tokens) == 5 for r in res)

    m_pal, params_p = serve.load_checkpoint(p, use_pallas=True)
    assert m_pal.cfg.use_pallas
    res_p = serve.generate(m_pal, params_p, prompts, **kw)
    for a, b in zip(res, res_p):
        assert a.tokens == b.tokens


# ------------------------------------------------------------ CNN classifier


def test_image_classifier_padding_and_buckets():
    model = build_model(get_config("squeezenet-dr"))
    params = model.init(jax.random.PRNGKey(1))
    clf = ImageClassifier(model, params, (1, 4))
    rng = np.random.default_rng(0)
    imgs = rng.normal(size=(6, 32, 32, 3)).astype(np.float32)
    out = clf.classify([Request(rid=i, image=imgs[i]) for i in range(6)])
    assert [o.bucket for o in out] == ["b4"] * 4 + ["b1"] * 2
    assert clf.compile_counts() == {"b1": 1, "b4": 1}
    direct = np.argmax(np.asarray(
        model.forward(params, {"images": jnp.asarray(imgs)})[0]), -1)
    assert [o.label for o in out] == direct.tolist()
    assert all(0.0 < o.confidence <= 1.0 for o in out)
