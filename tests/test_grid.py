"""Grid-engine parity/property harness (PR 4).

Locks down the hyper-parameter grid axis: the vmapped ``run_grid``
program must lower to ONE executable and reproduce each serial
``run_grid_point`` slice bit-for-bit (same PRNG keys); a padded-k grid
row must reproduce a natively smaller-k run bitwise (the masked
static-max k-means + pad-stable fold_in RNG contract); the default
grid point must be bitwise the Table-II bso-sl method path; and the
local-step / lr overrides must have their masked-no-op semantics.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import OptimizerConfig, SwarmConfig
from repro.core.baselines import (run_grid_point, run_grid_table, run_method,
                                  sweep_keys)
from repro.core.engine import (EngineConfig, GridPoint, grid_axes, grid_point,
                               jit_run_grid, jit_run_rounds, make_grid_config,
                               make_grid_state, make_swarm_data,
                               make_swarm_state, method_params, run_grid)
from repro.core.kmeans import kmeans
from repro.core.swarm import SwarmTrainer
from repro.data.dr import TABLE_I, make_dr_swarm_data
from repro.models import build_model
from repro.optim.optimizers import make_optimizer

SMALL_TABLE = np.maximum(TABLE_I // 16, (TABLE_I > 0).astype(np.int64) * 2)
N = TABLE_I.shape[1]
OPT = OptimizerConfig(name="adam", lr=2e-3)

#: the acceptance grid: k x p1, 6 points, one executable
ACCEPTANCE_AXES = dict(k=(1, 2, 3), p1=(0.9, 1.0))


@pytest.fixture(scope="module")
def dr_clients():
    return make_dr_swarm_data(image_size=16, seed=0, table=SMALL_TABLE)


@pytest.fixture(scope="module")
def dr_model():
    return build_model(get_config("squeezenet-dr"))


def _swarm(rounds=2, local_steps=2, n_clusters=3):
    return SwarmConfig(n_clients=N, n_clusters=n_clusters, rounds=rounds,
                       local_steps=local_steps, kmeans_iters=10)


def _cfg(model, *, local_steps=2, n_clusters=3):
    return EngineConfig(model=model,
                        opt=make_optimizer(OPT), local_steps=local_steps,
                        batch_size=8, lr=2e-3, aggregation="bso",
                        n_clusters=n_clusters, p1=0.9, p2=0.8,
                        kmeans_iters=10)


def _params_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ----------------------------------------------------- one-program property


def test_grid_smoke_one_program(dr_clients, dr_model):
    """Fail-fast stage for test.sh: the k{1,2,3} x p1{0.9,1.0}
    acceptance grid lowers to ONE executable, runs 2 rounds, and
    produces finite well-formed metrics; repeated grids hit the jit
    cache (the compile-count assertion)."""
    cfg = _cfg(dr_model)
    data = make_swarm_data(dr_model.cfg, dr_clients)
    specs = grid_axes(**ACCEPTANCE_AXES)
    G = len(specs)
    keys = jax.random.split(jax.random.PRNGKey(0), G)
    states = make_grid_state(dr_model, cfg.opt, dr_clients, keys)
    grid = make_grid_config(cfg, N, specs)

    # one lowering == one device program for the whole G-point ablation
    lowered = jax.jit(run_grid, static_argnames=("cfg", "rounds")).lower(
        states, data, cfg, grid, 2)
    compiled = lowered.compile()
    s, ms = compiled(states, data, grid)

    assert np.asarray(ms.mean_val_acc).shape == (G, 2)
    assert np.isfinite(np.asarray(ms.mean_val_acc)).all()
    assert np.isfinite(np.asarray(ms.train_loss)).all()
    assert np.asarray(ms.assignments).shape == (G, 2, N)
    # every row's assignments stay inside its own (traced) k
    ks = np.asarray(grid.n_clusters)
    assert (np.asarray(ms.assignments).max(axis=(1, 2)) < ks).all()
    assert (np.asarray(s.round) == 2).all()

    # module-level entry point: at most one compile, then cache hits
    states = make_grid_state(dr_model, cfg.opt, dr_clients, keys)
    n0 = jit_run_grid._cache_size()
    s2, _ = jit_run_grid(states, data, cfg, grid, 2)
    n1 = jit_run_grid._cache_size()
    assert n1 <= n0 + 1
    s2 = jax.tree.map(jnp.copy, s2)
    jit_run_grid(s2, data, cfg, grid, 2)
    assert jit_run_grid._cache_size() == n1, "run_grid recompiled"


# ------------------------------------------------- grid vs serial parity


def test_grid_rows_match_serial_oracle(dr_clients, dr_model):
    """The parity contract: row g of one vmapped run_grid program ==
    the serial run_grid_point slice seeded with the same key — allclose
    per-round accuracies, bitwise-equal final params."""
    swarm = _swarm()
    key = jax.random.PRNGKey(42)
    results, grid_run = run_grid_table(dr_model, dr_clients, swarm, OPT, key,
                                       axes=ACCEPTANCE_AXES, batch_size=8)
    specs = grid_axes(**ACCEPTANCE_AXES)
    keys = sweep_keys(key, specs)
    for g, spec in enumerate(specs):
        acc, serial = run_grid_point(spec, dr_model, dr_clients, swarm, OPT,
                                     keys[g], batch_size=8)
        np.testing.assert_allclose(
            np.asarray(grid_run.metrics.mean_val_acc[g]),
            np.asarray(serial.metrics.mean_val_acc),
            rtol=1e-6, atol=1e-7, err_msg=str(spec))
        np.testing.assert_allclose(results[g]["acc"], acc,
                                   rtol=1e-6, atol=1e-7)
        _params_equal(jax.tree.map(lambda x: x[g], grid_run.state.params),
                      serial.state.params)
        np.testing.assert_array_equal(
            np.asarray(grid_run.metrics.assignments[g]),
            np.asarray(serial.metrics.assignments), err_msg=str(spec))


def test_padded_k_matches_native_smaller_k(dr_clients, dr_model):
    """A grid row with k=2 under the static pad k_max=3 is bitwise the
    native n_clusters=2 run (the static method path): the fold_in RNG
    scheme makes the first k_active cluster draws pad-invariant, and
    the masked k-means/brain-storm never let a dead slot act."""
    key = jax.random.PRNGKey(3)
    data = make_swarm_data(dr_model.cfg, dr_clients)

    cfg_pad = _cfg(dr_model, n_clusters=3)
    state = make_swarm_state(dr_model, cfg_pad.opt, dr_clients, key)
    s_pad, m_pad = jit_run_rounds(state, data, cfg_pad, 2,
                                  grid_point(cfg_pad, N, k=2))

    cfg_nat = _cfg(dr_model, n_clusters=2)
    state = make_swarm_state(dr_model, cfg_nat.opt, dr_clients, key)
    s_nat, m_nat = jit_run_rounds(state, data, cfg_nat, 2,
                                  method_params("bso-sl", N))

    _params_equal(s_pad.params, s_nat.params)
    _params_equal(s_pad.opt_state, s_nat.opt_state)
    np.testing.assert_array_equal(np.asarray(m_pad.assignments),
                                  np.asarray(m_nat.assignments))
    # centers agree on the live slots; the pad slot is always empty
    np.testing.assert_array_equal(np.asarray(m_pad.centers)[:, :2],
                                  np.asarray(m_nat.centers))
    assert (np.asarray(m_pad.centers)[:, 2] == -1).all()


def test_masked_kmeans_matches_native_k():
    """Unit-level pad-invariance: kmeans(k=k_max, k_active=j) ==
    kmeans(k=j) — identical assignments, and live centroids equal up
    to the (k-dependent) matmul reduction tiling of the mean step —
    for every j <= k_max, on arbitrary feature matrices."""
    X = jax.random.normal(jax.random.PRNGKey(0), (20, 5))
    key = jax.random.PRNGKey(1)
    for j in (1, 2, 3, 4):
        C_nat, a_nat = kmeans(key, X, k=j, iters=8)
        C_pad, a_pad = kmeans(key, X, k=4, iters=8,
                              k_active=jnp.asarray(j, jnp.int32))
        np.testing.assert_array_equal(np.asarray(a_pad), np.asarray(a_nat))
        np.testing.assert_allclose(np.asarray(C_pad)[:j],
                                   np.asarray(C_nat),
                                   rtol=1e-6, atol=1e-7)


def test_default_grid_point_matches_run_method(dr_clients, dr_model):
    """The empty spec IS the paper point: run_grid_point({}) is bitwise
    run_method('bso-sl') with the same key — the bridge between the
    grid axis and the Table-II method axis."""
    swarm = _swarm()
    key = jax.random.PRNGKey(9)
    acc_m, rm = run_method("bso-sl", dr_model, dr_clients, swarm, OPT, key,
                           batch_size=8)
    acc_g, rg = run_grid_point({}, dr_model, dr_clients, swarm, OPT, key,
                               batch_size=8)
    assert acc_m == acc_g
    _params_equal(rm.state.params, rg.state.params)
    np.testing.assert_array_equal(np.asarray(rm.metrics.assignments),
                                  np.asarray(rg.metrics.assignments))


def test_grid_row_matches_swarm_trainer_slice(dr_clients, dr_model):
    """A default grid row reproduces the stateful SwarmTrainer fit when
    both share one PRNG chain: make_swarm_state(key) splits key into
    (init, round) keys, so SwarmTrainer(key).fit(split(key)[1]) walks
    the identical schedule."""
    key = jax.random.PRNGKey(17)
    swarm = _swarm(rounds=2, local_steps=2)
    acc, rg = run_grid_point({}, dr_model, dr_clients, swarm, OPT, key,
                             batch_size=8)
    tr = SwarmTrainer(dr_model, dr_clients, swarm, OPT, key, batch_size=8,
                      aggregation="bso")
    tr.fit(jax.random.split(key)[1])
    _params_equal(tr.params, rg.state.params)
    np.testing.assert_allclose(
        [l.mean_val_acc for l in tr.history],
        np.asarray(rg.metrics.mean_val_acc), rtol=1e-6, atol=1e-7)


# --------------------------------------------------- knob semantics


def test_local_steps_and_lr_override_semantics(dr_clients, dr_model):
    """Masked local steps: a row running all static steps is bitwise
    the unmasked path (covered above); a row with lr=0 must leave
    params exactly at their cluster-aggregated initial values — adam's
    zero-lr update is the identity on params — proving the traced lr
    actually reaches the train step."""
    cfg = _cfg(dr_model)
    data = make_swarm_data(dr_model.cfg, dr_clients)
    key = jax.random.PRNGKey(5)
    state = make_swarm_state(dr_model, cfg.opt, dr_clients, key)
    p0 = jax.tree.map(jnp.copy, state.params)
    s, m = jit_run_rounds(state, data, cfg, 1, grid_point(cfg, N, lr=0.0))
    # local identity + Eq.2 redistribution: every client's params are a
    # convex combination of the *initial* params of its cluster
    from repro.core.aggregation import cluster_fedavg
    expect = cluster_fedavg(p0, m.assignments[0], s.n_samples, k=N)
    _params_equal(s.params, expect)

    # fewer active steps changes the trajectory (the mask is not a
    # no-op) but stays well-formed
    state = make_swarm_state(dr_model, cfg.opt, dr_clients, key)
    s1, m1 = jit_run_rounds(state, data, cfg, 1,
                            grid_point(cfg, N, local_steps=1))
    state = make_swarm_state(dr_model, cfg.opt, dr_clients, key)
    s2, m2 = jit_run_rounds(state, data, cfg, 1, grid_point(cfg, N))
    diffs = [not np.array_equal(np.asarray(a), np.asarray(b))
             for a, b in zip(jax.tree.leaves(s1.params),
                             jax.tree.leaves(s2.params))]
    assert any(diffs), "local_steps mask had no effect"
    assert np.isfinite(float(m1.train_loss[0]))


def test_grid_scheduled_matches_masked_path(dr_clients, dr_model):
    """Satellite: heterogeneous local_steps ride the sorted scan
    schedule — ONE compiled program, rows exit at their own budget.
    Metrics are bitwise the masked path's; params are allclose (~1 ulp:
    prefix segments batch the train step over g < G lanes and XLA's
    conv reduction order is lane-width-dependent), with rows that never
    leave the full-width segment bitwise."""
    cfg = _cfg(dr_model)
    data = make_swarm_data(dr_model.cfg, dr_clients)
    specs = [{"local_steps": 2}, {"local_steps": 1},
             {"local_steps": 1, "k": 2}]
    schedule = tuple(s["local_steps"] for s in specs)
    grid = make_grid_config(cfg, N, specs)
    keys = jax.random.split(jax.random.PRNGKey(11), len(specs))

    def mk():
        return make_grid_state(dr_model, cfg.opt, dr_clients, keys)

    # one lowering == one device program for the scheduled grid
    lowered = jax.jit(run_grid,
                      static_argnames=("cfg", "rounds", "schedule")).lower(
        mk(), data, cfg, grid, 1, schedule)
    s_s, m_s = lowered.compile()(mk(), data, grid)
    s_m, m_m = jit_run_grid(mk(), data, cfg, grid, 1)

    np.testing.assert_array_equal(np.asarray(m_m.val_acc),
                                  np.asarray(m_s.val_acc))
    np.testing.assert_array_equal(np.asarray(m_m.train_loss),
                                  np.asarray(m_s.train_loss))
    np.testing.assert_array_equal(np.asarray(m_m.assignments),
                                  np.asarray(m_s.assignments))
    for x, y in zip(jax.tree.leaves(s_m.params), jax.tree.leaves(s_s.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-6)
    # min-step rows only ever run in the full-width segment -> bitwise
    for g in (1, 2):
        _params_equal(jax.tree.map(lambda x: x[g], s_m.params),
                      jax.tree.map(lambda x: x[g], s_s.params))

    # compile-count stays 1: the module entry point compiles the
    # (cfg, rounds, schedule) signature once, then cache-hits
    n0 = jit_run_grid._cache_size()
    s1, _ = jit_run_grid(mk(), data, cfg, grid, 1, schedule)
    n1 = jit_run_grid._cache_size()
    assert n1 <= n0 + 1
    jit_run_grid(jax.tree.map(jnp.copy, s1), data, cfg, grid, 1, schedule)
    assert jit_run_grid._cache_size() == n1, "scheduled run_grid retraced"


def test_grid_table_derives_schedule_and_matches_serial(dr_clients,
                                                        dr_model):
    """run_grid_table auto-derives the schedule from a heterogeneous
    local_steps axis; each row still tracks the serial run_grid_point
    oracle (allclose — the scheduled path's contract)."""
    swarm = _swarm()
    key = jax.random.PRNGKey(23)
    axes = dict(local_steps=(1, 2))
    results, grid_run = run_grid_table(dr_model, dr_clients, swarm, OPT,
                                       key, axes=axes, batch_size=8)
    specs = grid_axes(**axes)
    keys = sweep_keys(key, specs)
    for g, spec in enumerate(specs):
        acc, serial = run_grid_point(spec, dr_model, dr_clients, swarm,
                                     OPT, keys[g], batch_size=8)
        np.testing.assert_allclose(
            np.asarray(grid_run.metrics.mean_val_acc[g]),
            np.asarray(serial.metrics.mean_val_acc),
            rtol=1e-5, atol=1e-6, err_msg=str(spec))
        np.testing.assert_allclose(results[g]["acc"], acc,
                                   rtol=1e-5, atol=1e-5)
        for x, y in zip(jax.tree.leaves(serial.state.params),
                        jax.tree.leaves(
                            jax.tree.map(lambda v: v[g],
                                         grid_run.state.params))):
            np.testing.assert_allclose(np.asarray(y), np.asarray(x),
                                       rtol=1e-5, atol=1e-6,
                                       err_msg=str(spec))


def test_grid_point_validates_against_static_maxima(dr_model):
    """k and local_steps outside [1, static max] fail at build time."""
    cfg = _cfg(dr_model)
    for bad in (dict(k=0), dict(k=4), dict(local_steps=0),
                dict(local_steps=3)):
        with pytest.raises(ValueError):
            grid_point(cfg, N, **bad)
    assert isinstance(grid_point(cfg, N, k=1, local_steps=1), GridPoint)


def test_grid_axes_row_major_product():
    specs = grid_axes(k=(1, 2), p1=(0.9, 1.0), p2=(0.8,))
    assert specs == [
        {"k": 1, "p1": 0.9, "p2": 0.8}, {"k": 1, "p1": 1.0, "p2": 0.8},
        {"k": 2, "p1": 0.9, "p2": 0.8}, {"k": 2, "p1": 1.0, "p2": 0.8}]
