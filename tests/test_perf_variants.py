"""Beyond-paper §Perf variants must preserve semantics:
grouped MoE dispatch, fp8 KV cache, padded-vocab readout."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def test_grouped_moe_matches_global_when_dropfree():
    cfg = dataclasses.replace(get_config("kimi-k2-1t-a32b").smoke(),
                              capacity_factor=8.0)
    cfg_g = dataclasses.replace(cfg, moe_grouped_dispatch=True, moe_groups=4)
    m, mg = build_model(cfg), build_model(cfg_g)
    params = m.init(KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)}
    a, _ = m.forward(params, batch)
    b, _ = mg.forward(params, batch)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_grouped_moe_trains():
    cfg = dataclasses.replace(get_config("llama4-maverick-400b-a17b").smoke(),
                              moe_grouped_dispatch=True, moe_groups=2)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size),
             "labels": jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)}
    loss, grads = jax.value_and_grad(lambda p: model.loss(p, batch)[0])(params)
    g = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(grads))
    assert np.isfinite(float(loss)) and np.isfinite(g) and g > 0


def test_fp8_kv_cache_decode_close_to_bf16():
    cfg = get_config("granite-3-2b").smoke()
    cfg8 = dataclasses.replace(cfg, cache_dtype="float8_e4m3fn")
    m, m8 = build_model(cfg), build_model(cfg8)
    params = m.init(KEY)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, cfg.vocab_size)
    c, c8 = m.init_cache(2, 10), m8.init_cache(2, 10)
    assert jax.tree.leaves(c8)[0].dtype == jnp.float8_e4m3fn
    for t in range(10):
        lr, c = m.decode_step(params, toks[:, t:t + 1], c, jnp.asarray(t, jnp.int32))
        l8, c8 = m8.decode_step(params, toks[:, t:t + 1], c8, jnp.asarray(t, jnp.int32))
    rel = float(jnp.max(jnp.abs(lr - l8))) / float(jnp.max(jnp.abs(lr)))
    assert np.isfinite(rel) and rel < 0.2, rel


def test_padded_vocab_loss_and_shapes():
    cfg = dataclasses.replace(get_config("granite-3-2b").smoke(),
                              vocab_round_to=128)
    model = build_model(cfg)
    params = model.init(KEY)
    assert params["embedding"]["table"].shape[0] == cfg.padded_vocab
    assert cfg.padded_vocab % 128 == 0 and cfg.padded_vocab >= cfg.vocab_size
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size),
             "labels": jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)}
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss))
    logits, _ = model.forward(params, batch)
    assert logits.shape[-1] == cfg.padded_vocab


def test_padded_vocab_noop_by_default():
    cfg = get_config("granite-3-2b")
    assert cfg.padded_vocab == cfg.vocab_size


def test_ring_cache_matches_sliding_window_decode():
    """O(window) ring-buffer cache must reproduce the full-cache
    sliding-window decode exactly (same absolute-position RoPE, same
    window contents)."""
    W = 8
    base = dataclasses.replace(get_config("granite-3-2b").smoke(),
                               sliding_window=W)
    ring = dataclasses.replace(base, cache_ring=True)
    mb, mr = build_model(base), build_model(ring)
    params = mb.init(KEY)
    S = 24                      # 3x the window: exercises wraparound
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, S), 0, base.vocab_size)
    cb = mb.init_cache(2, S)
    cr = mr.init_cache(2, S)
    # ring cache is W-sized regardless of requested max_seq
    assert jax.tree.leaves(cr)[0].shape[1] == W
    for t in range(S):
        lb, cb = mb.decode_step(params, toks[:, t:t + 1], cb,
                                jnp.asarray(t, jnp.int32))
        lr, cr = mr.decode_step(params, toks[:, t:t + 1], cr,
                                jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(np.asarray(lr), np.asarray(lb),
                                   rtol=2e-4, atol=2e-5, err_msg=f"pos {t}")
