# NOTE: deliberately NO xla_force_host_platform_device_count here —
# smoke tests and benches must see the single real CPU device; only
# repro.launch.dryrun / swarm_fleet set the 512-device stand-in flag.
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="run tests marked slow (extra seeds of the statistical "
             "parity tests — the nightly tier; tier-1 runs one seed)")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: extra-seed replicas of statistical tests; skipped unless "
        "--runslow (nightly) — tier-1 keeps one pinned seed per test")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="needs --runslow (nightly tier)")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
