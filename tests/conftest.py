# NOTE: deliberately NO xla_force_host_platform_device_count here —
# smoke tests and benches must see the single real CPU device; only
# repro.launch.dryrun / swarm_fleet set the 512-device stand-in flag.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
