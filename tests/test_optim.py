"""Optimizer unit tests (hand-rolled: no optax offline)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OptimizerConfig
from repro.optim.optimizers import make_optimizer
from repro.optim.schedules import make_schedule


@pytest.mark.parametrize("name", ["sgd", "momentum", "adam", "adamw",
                                  "adafactor"])
def test_optimizer_descends_quadratic(name):
    opt = make_optimizer(OptimizerConfig(name=name, lr=0.1, grad_clip=0))
    params = {"w": jnp.asarray([3.0, -2.0]), "m": jnp.asarray([[1.5, 0.5]] * 2)}

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["m"] ** 2)

    state = opt.init(params)
    l0 = float(loss(params))
    for _ in range(50):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params, 0.1)
    assert float(loss(params)) < l0 * 0.05


def test_adafactor_state_is_factored():
    opt = make_optimizer(OptimizerConfig(name="adafactor"))
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((64,))}
    state = opt.init(params)
    assert state["v"]["w"]["vr"].shape == (64,)
    assert state["v"]["w"]["vc"].shape == (32,)
    assert state["v"]["b"]["v"].shape == (64,)
    # total state size << param size for matrices
    assert state["v"]["w"]["vr"].size + state["v"]["w"]["vc"].size < 64 * 32 / 5


def test_grad_clipping_bounds_update():
    opt = make_optimizer(OptimizerConfig(name="sgd", lr=1.0, grad_clip=1.0))
    params = {"w": jnp.zeros((4,))}
    state = opt.init(params)
    huge = {"w": jnp.full((4,), 1e6)}
    new, _ = opt.update(huge, state, params, 1.0)
    assert float(jnp.linalg.norm(new["w"])) <= 1.0 + 1e-5


def test_adam_bias_correction_first_step():
    opt = make_optimizer(OptimizerConfig(name="adam", lr=1.0, grad_clip=0,
                                         eps=0.0))
    params = {"w": jnp.asarray([0.0])}
    state = opt.init(params)
    g = {"w": jnp.asarray([0.5])}
    new, _ = opt.update(g, state, params, 1.0)
    # bias-corrected first step = lr * sign(g)
    np.testing.assert_allclose(np.asarray(new["w"]), [-1.0], rtol=1e-5)


def test_schedules():
    s = make_schedule("cosine", 1.0, warmup=10, total_steps=100)
    assert float(s(0)) < 0.2
    assert float(s(10)) > 0.9
    assert float(s(99)) < 0.2
    c = make_schedule("constant", 0.5)
    assert float(c(1234)) == 0.5
