"""Ragged bucketed-layout parity harness (PR 6).

Locks down the size-bucketed swarm layout against the rectangular
pad-to-global-max baseline:

* a full BSO-SL fit over :class:`~repro.core.engine.BucketedSwarmData`
  is BITWISE the :class:`~repro.core.engine.SwarmData` fit — sampling
  draws the identical global index tensor and bucketed eval drops only
  all-pad microbatches whose (hits, total) contribution is exactly
  +0.0,
* the pooled centralized gather (`_gather_bucketed_rows`) and the
  layout-dispatched ``eval_swarm`` are each bitwise their rectangular
  siblings,
* pad accounting: bucketing a Table-I-skewed swarm cuts the stored
  train pad fraction >= 2x (the ``BENCH_bucket.json`` acceptance
  floor),
* edge cases of the padding/sampling contracts: clients smaller than
  one eval microbatch, clients exactly at a power-of-two bucket
  boundary, a single-client swarm, and pad rows never sampled / never
  scored (label=-1 exclusion),
* the ``param_stats_batched`` Pallas kernel in ``interpret=True`` mode
  over the ragged per-bucket client stacks — the kernel path exercised
  on the bucketed shapes without a TPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import OptimizerConfig
from repro.core.engine import (BucketedSwarmData, EngineConfig, eval_swarm,
                               jit_run_rounds, make_bucketed_swarm_data,
                               make_client_eval, make_swarm_data,
                               make_swarm_state, method_params, pad_fraction,
                               sample_round_batch, stack_eval_split)
from repro.data.dr import TABLE_I, bucket_clients, make_dr_swarm_data
from repro.kernels import ops, ref
from repro.models import build_model
from repro.optim.optimizers import make_optimizer

SMALL_TABLE = np.maximum(TABLE_I // 16, (TABLE_I > 0).astype(np.int64) * 2)
N = TABLE_I.shape[1]


@pytest.fixture(scope="module")
def dr_clients():
    return make_dr_swarm_data(image_size=8, seed=0, table=SMALL_TABLE)


@pytest.fixture(scope="module")
def dr_model():
    return build_model(get_config("squeezenet-dr"))


@pytest.fixture(scope="module")
def rect_data(dr_model, dr_clients):
    return make_swarm_data(dr_model.cfg, dr_clients)


@pytest.fixture(scope="module")
def buck_data(dr_model, dr_clients):
    return make_bucketed_swarm_data(dr_model.cfg, dr_clients)


def _cfg(model, **kw):
    kw.setdefault("local_steps", 2)
    kw.setdefault("kmeans_iters", 5)
    return EngineConfig(model=model, opt=make_optimizer(
        OptimizerConfig(name="adam", lr=2e-3)), batch_size=4, lr=2e-3,
        aggregation="bso", n_clusters=3, **kw)


def _params_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ----------------------------------------------------- layout invariants


def test_bucketed_layout_well_formed(dr_clients, buck_data):
    """client_ids partition range(N); each bucket's train stack is
    padded to its own ceiling, not the global maximum."""
    ids = sorted(i for b in buck_data.client_ids for i in b)
    assert ids == list(range(N))
    sizes = np.asarray(buck_data.train_n)
    n_global = int(sizes.max())
    own_ceilings = []
    for b, tr in zip(buck_data.client_ids, buck_data.train):
        stack = jax.tree.leaves(tr)[0]
        assert stack.shape[0] == len(b)
        assert stack.shape[1] == int(sizes[np.asarray(b)].max())
        own_ceilings.append(stack.shape[1])
    assert min(own_ceilings) < n_global, "bucketing did not shrink any pad"


def test_pad_fraction_reduced_2x(dr_model, dr_clients, rect_data, buck_data):
    """The acceptance floor: the Table-I size skew makes the stored
    train pad fraction drop >= 2x under bucketing; with an eval
    microbatch that does not quantise every client to one ceiling, the
    total stored-pad fraction drops >= 2x as well."""
    pf_r, pf_b = pad_fraction(rect_data), pad_fraction(buck_data)
    assert pf_r["real_rows"] == pf_b["real_rows"]
    assert pf_b["stored_rows"] < pf_r["stored_rows"]
    assert pf_r["train"] / max(pf_b["train"], 1e-9) >= 2.0
    rect4 = make_swarm_data(dr_model.cfg, dr_clients, eval_batch=4)
    buck4 = make_bucketed_swarm_data(dr_model.cfg, dr_clients, eval_batch=4)
    assert (pad_fraction(rect4)["total"]
            / max(pad_fraction(buck4)["total"], 1e-9)) >= 2.0


# ----------------------------------------------------- bitwise parity


def test_bucketed_run_rounds_bitwise_rect(dr_model, dr_clients, rect_data,
                                          buck_data):
    """The oracle: a 2-round BSO-SL fit over the bucketed layout is
    bitwise the rectangular fit — same key, same metrics, same final
    params."""
    cfg = _cfg(dr_model)
    s_r = make_swarm_state(dr_model, cfg.opt, dr_clients,
                           jax.random.PRNGKey(0))
    s_b = make_swarm_state(dr_model, cfg.opt, dr_clients,
                           jax.random.PRNGKey(0))
    s_r, m_r = jit_run_rounds(s_r, rect_data, cfg, 2)
    s_b, m_b = jit_run_rounds(s_b, buck_data, cfg, 2)
    np.testing.assert_array_equal(np.asarray(m_r.val_acc),
                                  np.asarray(m_b.val_acc))
    np.testing.assert_array_equal(np.asarray(m_r.train_loss),
                                  np.asarray(m_b.train_loss))
    np.testing.assert_array_equal(np.asarray(m_r.assignments),
                                  np.asarray(m_b.assignments))
    _params_equal(s_r.params, s_b.params)


def test_bucketed_centralized_pooled_bitwise(dr_model, dr_clients,
                                             rect_data, buck_data):
    """The pooled-sampling centralized method rides the bucketed gather
    (`_gather_bucketed_rows`): one round, bitwise params."""
    cfg = _cfg(dr_model)
    meth = method_params("centralized", N)
    s_r = make_swarm_state(dr_model, cfg.opt, dr_clients,
                           jax.random.PRNGKey(1))
    s_b = make_swarm_state(dr_model, cfg.opt, dr_clients,
                           jax.random.PRNGKey(1))
    s_r, m_r = jit_run_rounds(s_r, rect_data, cfg, 1, meth)
    s_b, m_b = jit_run_rounds(s_b, buck_data, cfg, 1, meth)
    np.testing.assert_array_equal(np.asarray(m_r.val_acc),
                                  np.asarray(m_b.val_acc))
    _params_equal(s_r.params, s_b.params)


def test_sample_round_batch_layout_bitwise(rect_data, buck_data):
    """Per-step minibatches are bitwise layout-independent, pooled or
    not, and never touch a pad row (train pads carry label=-1)."""
    for i in range(3):
        key = jax.random.PRNGKey(100 + i)
        b_r = sample_round_batch(key, rect_data, 16)
        b_b = sample_round_batch(key, buck_data, 16)
        for x, y in zip(jax.tree.leaves(b_r), jax.tree.leaves(b_b)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert (np.asarray(b_b["labels"]) >= 0).all()
        for pool in (False, True):
            b_r = sample_round_batch(key, rect_data, 16, jnp.asarray(pool))
            b_b = sample_round_batch(key, buck_data, 16, jnp.asarray(pool))
            for x, y in zip(jax.tree.leaves(b_r), jax.tree.leaves(b_b)):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
            assert (np.asarray(b_b["labels"]) >= 0).all()


def test_eval_swarm_layout_bitwise(dr_model, dr_clients, rect_data,
                                   buck_data):
    """The bucketed masked segment reduction scores every client
    bitwise the rectangular vmapped eval."""
    params = jax.vmap(dr_model.init)(
        jax.random.split(jax.random.PRNGKey(2), N))
    a_r = eval_swarm(dr_model, params, rect_data)
    a_b = eval_swarm(dr_model, params, buck_data)
    np.testing.assert_array_equal(np.asarray(a_r), np.asarray(a_b))


# ----------------------------------------------------- edge cases


def test_client_smaller_than_one_eval_microbatch(dr_model):
    """A client with fewer rows than the eval microbatch pads to one
    batch whose tail is label=-1, and its accuracy equals the direct
    per-row accuracy over ONLY the real rows (pads never scored)."""
    table = SMALL_TABLE[:, :3]
    clients = make_dr_swarm_data(image_size=8, seed=0, table=table)
    stacked = stack_eval_split(dr_model.cfg, clients, "val", batch=64)
    labels = np.asarray(stacked["labels"])
    assert (labels == -1).any(), "expected pad rows below one microbatch"
    params = dr_model.init(jax.random.PRNGKey(0))
    sparams = jax.tree.map(lambda x: jnp.stack([x] * len(clients)), params)
    accs = np.asarray(make_client_eval(dr_model)(sparams, stacked))
    from repro.train.steps import make_eval_step
    ev = jax.jit(make_eval_step(dr_model))
    for i, c in enumerate(clients):
        X, y = c["val"]
        hits = 0
        for j in range(len(y)):
            m = ev(params, {"images": jnp.asarray(X[j:j + 1]),
                            "labels": jnp.asarray(y[j:j + 1])})
            hits += float(m["acc"])
        np.testing.assert_allclose(accs[i], hits / len(y), rtol=1e-6,
                                   atol=1e-6)


def test_client_exactly_at_bucket_boundary():
    """An exact power-of-two size is its own ceiling — it does NOT spill
    into the next bucket, so its stack carries zero pad rows."""
    groups = bucket_clients([8, 9, 16], max_buckets=4)
    as_sets = [set(g.tolist()) for g in groups]
    assert {0} in as_sets            # size 8 -> ceiling 8, alone
    assert {1, 2} in as_sets         # 9 and 16 share ceiling 16


def test_single_client_swarm(dr_model):
    """N=1: one bucket, bucketed data bitwise the rectangular data, and
    both layouts sample identical batches."""
    clients = make_dr_swarm_data(image_size=8, seed=0,
                                 table=SMALL_TABLE[:, :1])
    rect = make_swarm_data(dr_model.cfg, clients)
    buck = make_bucketed_swarm_data(dr_model.cfg, clients)
    assert buck.n_buckets == 1 and buck.client_ids == ((0,),)
    for x, y in zip(jax.tree.leaves(rect.train),
                    jax.tree.leaves(buck.train[0])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    key = jax.random.PRNGKey(4)
    b_r = sample_round_batch(key, rect, 8)
    b_b = sample_round_batch(key, buck, 8)
    for x, y in zip(jax.tree.leaves(b_r), jax.tree.leaves(b_b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    params = jax.tree.map(lambda x: x[None],
                          dr_model.init(jax.random.PRNGKey(0)))
    np.testing.assert_array_equal(
        np.asarray(eval_swarm(dr_model, params, rect)),
        np.asarray(eval_swarm(dr_model, params, buck)))


def test_pad_rows_never_scored(dr_model, dr_clients):
    """Poisoning every pad row's inputs must not move any accuracy:
    the label=-1 mask alone decides what scores."""
    stacked = stack_eval_split(dr_model.cfg, dr_clients, "val", batch=8)
    labels = np.asarray(stacked["labels"])
    assert (labels == -1).any()
    poisoned = dict(stacked)
    imgs = np.asarray(stacked["images"]).copy()
    imgs[labels == -1] = 1e6
    poisoned["images"] = jnp.asarray(imgs)
    params = jax.vmap(dr_model.init)(
        jax.random.split(jax.random.PRNGKey(5), N))
    ev = make_client_eval(dr_model)
    np.testing.assert_array_equal(np.asarray(ev(params, stacked)),
                                  np.asarray(ev(params, poisoned)))


# ------------------------------------------- Pallas kernel on ragged stacks


def test_param_stats_batched_interpret_over_bucket_stacks(buck_data):
    """The distribution-stat kernel in interpret mode (CI has no TPU)
    over each ragged bucket stack — one (N_b, n_max_b*H*W*C) client
    matrix per bucket signature — vs the jnp oracle."""
    shapes = set()
    for tr in buck_data.train:
        x = jnp.asarray(np.asarray(tr["images"], np.float32))
        x = x.reshape(x.shape[0], -1)
        shapes.add(x.shape)
        m, v = ops.param_stats_batched(x, interpret=True)
        rm, rv = ref.ref_param_stats_batched(x)
        assert m.shape == (x.shape[0],)
        np.testing.assert_allclose(np.asarray(m), np.asarray(rm),
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(v), np.asarray(rv),
                                   rtol=1e-2, atol=1e-2)
    assert len(shapes) > 1, "bucket stacks were not ragged"


def test_bucketed_data_is_a_pytree(buck_data):
    """BucketedSwarmData round-trips jax.tree.map with the static
    client_ids preserved — the jit-cache-key discipline."""
    mapped = jax.tree.map(lambda x: x, buck_data)
    assert isinstance(mapped, BucketedSwarmData)
    assert mapped.client_ids == buck_data.client_ids
    assert mapped.n_buckets == buck_data.n_buckets
