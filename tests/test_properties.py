"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.aggregation import (cluster_fedavg, cluster_fedavg_masked,
                                    cluster_fedavg_psum_masked, fedavg)
from repro.core.bso import brain_storm, brain_storm_jax
from repro.core.kmeans import kmeans
from repro.kernels import ops, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

floats = st.floats(-10, 10, allow_nan=False, allow_infinity=False, width=32)


# ------------------------------------------------------------- aggregation

@given(st.lists(floats, min_size=2, max_size=6),
       st.integers(1, 1000))
def test_fedavg_of_identical_params_is_identity(vals, w):
    """Aggregating N copies of the same model returns that model."""
    t = {"w": jnp.asarray(vals, jnp.float32)}
    out = fedavg([t, t, t], [w, 2 * w, 3 * w])
    np.testing.assert_allclose(np.asarray(out["w"]), vals, rtol=1e-5, atol=1e-5)


@given(st.integers(2, 10), st.integers(1, 4), st.integers(0, 2 ** 31 - 1))
def test_cluster_fedavg_is_convex_combination(n, k, seed):
    """Every aggregated leaf lies within [min, max] of cluster members."""
    rng = np.random.default_rng(seed)
    stacked = {"w": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)}
    assignments = jnp.asarray(rng.integers(0, k, size=n))
    weights = jnp.asarray(rng.uniform(0.5, 5.0, size=n), jnp.float32)
    out = np.asarray(cluster_fedavg(stacked, assignments, weights, k=k)["w"])
    W = np.asarray(stacked["w"])
    a = np.asarray(assignments)
    for i in range(n):
        members = W[a == a[i]]
        assert out[i].min() >= members.min() - 1e-4
        assert out[i].max() <= members.max() + 1e-4


@given(st.integers(2, 8), st.integers(0, 2 ** 31 - 1))
def test_cluster_fedavg_idempotent(n, seed):
    """Aggregating twice equals aggregating once (fixed point)."""
    rng = np.random.default_rng(seed)
    stacked = {"w": jnp.asarray(rng.normal(size=(n, 4)), jnp.float32)}
    assignments = jnp.asarray(rng.integers(0, 2, size=n))
    weights = jnp.asarray(rng.uniform(1, 3, size=n), jnp.float32)
    once = cluster_fedavg(stacked, assignments, weights, k=2)
    twice = cluster_fedavg(once, assignments, weights, k=2)
    np.testing.assert_allclose(np.asarray(twice["w"]), np.asarray(once["w"]),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------- masked aggregation (churn Eq. 2)

def _masked_case(n, k, seed, drop_frac=0.0, zero_cluster=False):
    """Random churn-Eq.2 inputs: stacked params, assignments, base
    |D_h| weights, and a presence mask (optionally forcing cluster 0's
    effective weight to zero)."""
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(n, 4)).astype(np.float32)
    a = rng.integers(0, k, size=n).astype(np.int32)
    base = rng.uniform(0.5, 5.0, size=n).astype(np.float32)
    present = rng.uniform(size=n) >= drop_frac
    if zero_cluster:
        present = present | True          # start all-present…
        present &= a != 0                 # …then hard-drop cluster 0
    if not present.any():
        present[0] = True
    weights = base * present.astype(np.float32)
    return W, a, base, weights, present


def _masked_oracle(W, a, weights, present, k):
    """Numpy reference for cluster_fedavg_masked: weighted per-cluster
    mean for present members of positively-weighted clusters, own
    params otherwise."""
    out = W.copy()
    tot = np.zeros(k, np.float64)
    sums = np.zeros((k,) + W.shape[1:], np.float64)
    for i in range(len(W)):
        tot[a[i]] += weights[i]
        sums[a[i]] += weights[i] * W[i]
    for i in range(len(W)):
        if present[i] and tot[a[i]] > 0.0:
            out[i] = (sums[a[i]] / tot[a[i]]).astype(np.float32)
    return out


@given(st.integers(3, 12), st.integers(1, 4), st.integers(0, 2 ** 31 - 1),
       st.floats(0.0, 0.8))
def test_cluster_fedavg_masked_matches_numpy_oracle(n, k, seed, drop):
    W, a, _, weights, present = _masked_case(n, k, seed, drop_frac=drop)
    out = cluster_fedavg_masked({"w": jnp.asarray(W)}, jnp.asarray(a),
                                jnp.asarray(weights), jnp.asarray(present),
                                k=k)["w"]
    np.testing.assert_allclose(np.asarray(out),
                               _masked_oracle(W, a, weights, present, k),
                               rtol=1e-5, atol=1e-5)


@given(st.integers(3, 12), st.integers(1, 4), st.integers(0, 2 ** 31 - 1),
       st.floats(0.0, 0.8))
def test_cluster_fedavg_masked_permutation_invariant(n, k, seed, drop):
    """Relabeling clients (permuting all per-client arrays together)
    permutes the output identically — no client is privileged."""
    W, a, _, weights, present = _masked_case(n, k, seed, drop_frac=drop)
    out = np.asarray(cluster_fedavg_masked(
        {"w": jnp.asarray(W)}, jnp.asarray(a), jnp.asarray(weights),
        jnp.asarray(present), k=k)["w"])
    perm = np.random.default_rng(seed ^ 0x5EED).permutation(n)
    out_p = np.asarray(cluster_fedavg_masked(
        {"w": jnp.asarray(W[perm])}, jnp.asarray(a[perm]),
        jnp.asarray(weights[perm]), jnp.asarray(present[perm]), k=k)["w"])
    np.testing.assert_allclose(out_p, out[perm], rtol=1e-5, atol=1e-6)


@given(st.integers(3, 12), st.integers(2, 4), st.integers(0, 2 ** 31 - 1))
def test_cluster_fedavg_masked_zero_weight_cluster_keeps_own(n, k, seed):
    """A cluster whose every member is hard-dropped aggregates nothing:
    its members keep their own params BITWISE (the zero-denominator
    guard), and no NaN ever surfaces."""
    W, a, _, weights, present = _masked_case(n, k, seed, zero_cluster=True)
    out = np.asarray(cluster_fedavg_masked(
        {"w": jnp.asarray(W)}, jnp.asarray(a), jnp.asarray(weights),
        jnp.asarray(present), k=k)["w"])
    assert not np.isnan(out).any()
    for i in range(n):
        if a[i] == 0 or not present[i]:
            assert np.array_equal(out[i], W[i])


@given(st.integers(3, 12), st.integers(1, 4), st.integers(0, 2 ** 31 - 1),
       st.floats(0.0, 0.8))
def test_cluster_fedavg_masked_stale_decay_zero_is_hard_mask(n, k, seed,
                                                            drop):
    """stale_decay = 0 semantics: base * 0.0**staleness (staleness > 0
    iff absent; numpy 0**0 == 1) is EXACTLY the hard mask
    base * present — the two churn options coincide at λ = 0."""
    W, a, base, _, present = _masked_case(n, k, seed, drop_frac=drop)
    staleness = (~present).astype(np.float32) * \
        np.random.default_rng(seed ^ 0xDECA).integers(
            1, 5, size=n).astype(np.float32)
    w_decay = base * np.float_power(0.0, staleness).astype(np.float32)
    w_hard = base * present.astype(np.float32)
    np.testing.assert_array_equal(w_decay, w_hard)
    out_d = cluster_fedavg_masked({"w": jnp.asarray(W)}, jnp.asarray(a),
                                  jnp.asarray(w_decay),
                                  jnp.asarray(present), k=k)["w"]
    out_h = cluster_fedavg_masked({"w": jnp.asarray(W)}, jnp.asarray(a),
                                  jnp.asarray(w_hard),
                                  jnp.asarray(present), k=k)["w"]
    assert np.array_equal(np.asarray(out_d), np.asarray(out_h))


@given(st.integers(3, 12), st.integers(1, 4), st.integers(0, 2 ** 31 - 1),
       st.floats(0.0, 0.8))
def test_cluster_fedavg_masked_mean_is_bounded_by_members(n, k, seed, drop):
    """Every receiving client's aggregate lies inside the [min, max]
    envelope of its cluster's positively-weighted members (weighted
    mean is a convex combination)."""
    W, a, _, weights, present = _masked_case(n, k, seed, drop_frac=drop)
    out = np.asarray(cluster_fedavg_masked(
        {"w": jnp.asarray(W)}, jnp.asarray(a), jnp.asarray(weights),
        jnp.asarray(present), k=k)["w"])
    tot = np.bincount(a, weights=weights, minlength=k)
    for i in range(n):
        if not (present[i] and tot[a[i]] > 0.0):
            continue
        members = W[(a == a[i]) & (weights > 0.0)]
        assert (out[i] >= members.min(axis=0) - 1e-4).all()
        assert (out[i] <= members.max(axis=0) + 1e-4).all()


@given(st.integers(3, 10), st.integers(1, 4), st.integers(0, 2 ** 31 - 1),
       st.floats(0.0, 0.8))
@settings(max_examples=10, deadline=None)
def test_cluster_fedavg_psum_masked_matches_segment_sum(n, k, seed, drop):
    """Fleet-regime masked psum Eq. 2 == sim-regime masked segment-sum
    on a 1-device 'pod' mesh (whole swarm in one shard; the psum is the
    identity reduction, so any divergence is in the shared math)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    W, a, _, weights, present = _masked_case(n, k, seed, drop_frac=drop)
    expect = cluster_fedavg_masked({"w": jnp.asarray(W)}, jnp.asarray(a),
                                   jnp.asarray(weights),
                                   jnp.asarray(present), k=k)["w"]
    mesh = jax.make_mesh((1,), ("pod",))

    def body(p, c, w, m):
        inner = jax.tree.map(lambda x: x[0], p)
        out = cluster_fedavg_psum_masked(inner, c[0], w[0], m[0], k, "pod")
        return jax.tree.map(lambda x: x[None], out)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P("pod"), P("pod"), P("pod"), P("pod")),
                   out_specs=P("pod"))
    got = fn({"w": jnp.asarray(W)[None]}, jnp.asarray(a)[None],
             jnp.asarray(weights)[None], jnp.asarray(present)[None])["w"][0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------------ kmeans

@given(st.integers(4, 30), st.integers(2, 5), st.integers(0, 2 ** 31 - 1))
def test_kmeans_assignment_is_locally_optimal(n, k, seed):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(n, 5)), jnp.float32)
    C, a = kmeans(jax.random.PRNGKey(seed % 1000), X, k, iters=15)
    d = np.asarray(jnp.sum((X[:, None] - C[None]) ** 2, axis=-1))
    a = np.asarray(a)
    for i in range(n):
        assert d[i, a[i]] <= d[i].min() + 1e-4


# -------------------------------------------------------------- brain storm

@given(st.integers(0, 10_000),
       st.floats(0, 1), st.floats(0, 1),
       st.integers(6, 20), st.integers(2, 4))
def test_brain_storm_invariants(seed, p1, p2, n, k):
    """For any (p1, p2): centers are valid members of their (post-swap)
    clusters; assignments remain a partition of the same client set."""
    rng = np.random.default_rng(seed)
    val = rng.uniform(size=n).astype(np.float32)
    assignments = rng.integers(0, k, size=n)
    plan = brain_storm(rng, assignments.copy(), val, k, p1, p2)
    assert sorted(plan.assignments.tolist()) != [] \
        and len(plan.assignments) == n
    # same multiset of cluster labels (swaps exchange, never create/destroy)
    assert sorted(plan.assignments.tolist()) == sorted(assignments.tolist())
    for c in range(k):
        if plan.centers[c] >= 0:
            assert plan.assignments[plan.centers[c]] == c


def _bsa_case(seed, n, k):
    rng = np.random.default_rng(seed)
    return (rng, rng.integers(0, k, size=n).astype(np.int32),
            rng.uniform(size=n).astype(np.float32))


@given(st.integers(0, 2 ** 31 - 1), st.integers(5, 24), st.integers(2, 5),
       st.floats(0, 1), st.floats(0, 1))
def test_brain_storm_jax_oracle_shared_invariants(seed, n, k, p1, p2):
    """For any (p1, p2, k, key): both implementations preserve the
    cluster-membership multiset, keep every center a member of its
    post-swap cluster, and bound event counts by the occupied-cluster
    count (each cluster initiates at most one replace and one swap)."""
    rng, a0, val = _bsa_case(seed, n, k)
    n_occ = len(np.unique(a0))

    a, c, n_rep, n_swap = brain_storm_jax(jax.random.PRNGKey(seed),
                                          a0, val, k, p1, p2)
    a, c = np.asarray(a), np.asarray(c)
    assert sorted(a.tolist()) == sorted(a0.tolist())
    for cl in range(k):
        if c[cl] >= 0:
            assert a[c[cl]] == cl
    assert 0 <= int(n_rep) <= n_occ
    assert 0 <= int(n_swap) <= n_occ

    plan = brain_storm(rng, a0.copy(), val, k, p1, p2)
    assert sorted(plan.assignments.tolist()) == sorted(a0.tolist())
    for cl in range(k):
        if plan.centers[cl] >= 0:
            assert plan.assignments[plan.centers[cl]] == cl
    assert sum("replace" in e for e in plan.events) <= n_occ
    assert sum("swap" in e for e in plan.events) <= n_occ


@given(st.integers(0, 2 ** 31 - 1), st.integers(5, 24), st.integers(2, 5))
def test_brain_storm_p_one_edge_is_deterministic_noop(seed, n, k):
    """p1 = p2 = 1: r > p never fires, so BOTH implementations are
    deterministic and must agree exactly — assignments untouched,
    centers = per-cluster best-validation member, zero events."""
    rng, a0, val = _bsa_case(seed, n, k)
    a, c, n_rep, n_swap = brain_storm_jax(jax.random.PRNGKey(seed),
                                          a0, val, k, 1.0, 1.0)
    plan = brain_storm(rng, a0.copy(), val, k, 1.0, 1.0)
    np.testing.assert_array_equal(np.asarray(a), a0)
    np.testing.assert_array_equal(plan.assignments, a0)
    np.testing.assert_array_equal(np.asarray(c), plan.centers)
    assert int(n_rep) == 0 and int(n_swap) == 0 and plan.events == []


@given(st.integers(0, 2 ** 31 - 1), st.integers(5, 24), st.integers(2, 5))
def test_brain_storm_p_zero_edge_always_fires(seed, n, k):
    """p1 = p2 = 0: every occupied cluster replaces its center with a
    random member and initiates a swap (when >= 2 clusters are
    occupied) — in both implementations. The invariants must survive
    maximum disruption."""
    rng, a0, val = _bsa_case(seed, n, k)
    n_occ = len(np.unique(a0))

    a, c, n_rep, n_swap = brain_storm_jax(jax.random.PRNGKey(seed),
                                          a0, val, k, 0.0, 0.0)
    a, c = np.asarray(a), np.asarray(c)
    assert sorted(a.tolist()) == sorted(a0.tolist())
    for cl in range(k):
        if c[cl] >= 0:
            assert a[c[cl]] == cl
    assert int(n_swap) == (n_occ if n_occ > 1 else 0)

    plan = brain_storm(rng, a0.copy(), val, k, 0.0, 0.0)
    n_swaps_np = sum("swap" in e for e in plan.events)
    assert n_swaps_np == (n_occ if n_occ > 1 else 0)
    for cl in range(k):
        if plan.centers[cl] >= 0:
            assert plan.assignments[plan.centers[cl]] == cl


# ------------------------------------------------------------------ kernels

@given(st.integers(1, 3), st.integers(1, 3), st.integers(0, 2 ** 31 - 1))
def test_param_stats_matches_numpy(r, c, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(r * 37, c * 11)) * 3, jnp.float32)
    m, v = ops.param_stats(x)
    np.testing.assert_allclose(float(m), float(np.mean(np.asarray(x))),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(v), float(np.var(np.asarray(x))),
                               rtol=1e-3, atol=1e-3)


@given(st.integers(1, 40), st.integers(1, 6), st.integers(2, 5),
       st.integers(0, 2 ** 31 - 1))
def test_kmeans_assign_kernel_matches_ref(n, f, k, seed):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(n, f)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(k, f)), jnp.float32)
    out = ops.kmeans_assign(X, C)
    expect = ref.ref_kmeans_assign(X, C)
    assert np.array_equal(np.asarray(out), np.asarray(expect))


# ----------------------------------------------------------------- softmax

@given(st.integers(1, 2), st.integers(1, 4), st.integers(0, 2 ** 31 - 1))
def test_flash_attention_rowsum_property(b, h, seed):
    """Attention output of constant-v inputs equals that constant
    (softmax rows sum to 1)."""
    rng = np.random.default_rng(seed)
    S, D = 128, 64
    q = jnp.asarray(rng.normal(size=(b, h, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, S, D)), jnp.float32)
    v = jnp.ones((b, h, S, D), jnp.float32) * 0.5
    out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(out), 0.5, rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- causality

@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_attention_is_causal(seed):
    """Perturbing a future token must not change past logits."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("granite-3-2b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    S = 12
    toks = rng.integers(0, cfg.vocab_size, size=(1, S)).astype(np.int32)
    t = int(rng.integers(1, S))
    toks2 = toks.copy()
    toks2[0, t] = (toks2[0, t] + 1) % cfg.vocab_size
    a, _ = model.forward(params, {"tokens": jnp.asarray(toks)})
    b, _ = model.forward(params, {"tokens": jnp.asarray(toks2)})
    np.testing.assert_allclose(np.asarray(a[:, :t]), np.asarray(b[:, :t]),
                               rtol=1e-5, atol=1e-5)
    assert float(jnp.max(jnp.abs(a[:, t:] - b[:, t:]))) > 1e-6


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=8, deadline=None)
def test_ssd_is_causal_and_state_consistent(seed):
    """Mamba2 SSD: (a) causality; (b) splitting a sequence in half and
    passing the final state must equal processing it whole."""
    import dataclasses
    from repro.configs import get_config
    from repro.models.ssm import apply_ssm, init_ssm
    cfg = dataclasses.replace(get_config("mamba2-370m").smoke(), ssm_chunk=8)
    p = init_ssm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    S = 32
    x = jnp.asarray(rng.normal(size=(1, S, cfg.d_model)) * 0.1, jnp.float32)
    y_full, state_full = apply_ssm(p, x, cfg)
    # causality
    x2 = x.at[0, S - 4].add(1.0)
    y2, _ = apply_ssm(p, x2, cfg)
    np.testing.assert_allclose(np.asarray(y2[:, :S - 4]),
                               np.asarray(y_full[:, :S - 4]),
                               rtol=1e-4, atol=1e-5)
    # carry passing (SSD state + conv boundary frames) across a split
    y_a, (st_a, conv_a) = apply_ssm(p, x[:, :S // 2], cfg, return_carry=True)
    y_b, st_b = apply_ssm(p, x[:, S // 2:], cfg, initial_state=st_a,
                          initial_conv=conv_a)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y_a, y_b], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_b), np.asarray(state_full),
                               rtol=1e-4, atol=1e-5)
