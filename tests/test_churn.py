"""Churn-robustness harness: participation masks, staleness-weighted
Eq. 2, and the fleet fault-injection/quorum regime (PR 8).

Acceptance properties:

* the churn-free anchor — a churn row with ``dropout=0`` (or an
  explicit all-ones mask) is BITWISE the plain ``run_rounds`` program:
  params, opt state, losses, accuracies and assignments (keys are
  consumed unconditionally, every mask op is a float identity),
* a dropout-robustness sweep is ONE vmapped executable whose rows
  reproduce the serial masked oracle bit-for-bit,
* churn semantics — absent clients are frozen bitwise for the round,
  staleness counters reset on participation, an all-absent cluster
  rides the k-means empty-cluster reseed and the masked Eq. 2's
  zero-weight guard (no NaNs, receivers keep their own params),
* the fleet regime — seeded fault injection replays deterministically,
  the quorum rule re-applies the previous decision below Q reports,
  the all-ones churn program is bitwise the churn-free driver, and the
  checkpoint-export fixes hold (periodic ``_r{R}`` == final export;
  ``rounds=0`` warns and still exports).
"""
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import OptimizerConfig, SwarmConfig
from repro.core.aggregation import cluster_fedavg, cluster_fedavg_masked
from repro.core.baselines import run_grid_point, run_grid_table, sweep_keys
from repro.core.engine import (EngineConfig, churn_params, grid_axes,
                               jit_run_grid, jit_run_rounds,
                               make_grid_config, make_grid_state,
                               make_swarm_data, make_swarm_state, run_grid)
from repro.core.kmeans import kmeans, lloyd_step
from repro.data.dr import TABLE_I, make_dr_swarm_data
from repro.launch.fleet_driver import (FleetFaults, draw_faults,
                                       host_coordinator, run_fleet)
from repro.launch.mesh import make_fleet_mesh
from repro.models import build_model
from repro.optim.optimizers import make_optimizer

N_CLIENTS = 8
SMALL_TABLE = np.maximum(TABLE_I // 16,
                         (TABLE_I > 0).astype(np.int64) * 2)[:, :N_CLIENTS]
OPT = OptimizerConfig(name="adam", lr=2e-3)

#: the acceptance churn grid: dropout x stale-decay, one executable
CHURN_AXES = dict(dropout=(0.0, 0.3), stale_decay=(0.0, 0.5))


@pytest.fixture(scope="module")
def dr_clients():
    return make_dr_swarm_data(image_size=16, seed=0, table=SMALL_TABLE)


@pytest.fixture(scope="module")
def dr_model():
    return build_model(get_config("squeezenet-dr"))


def _cfg(model, *, local_steps=2, n_clusters=3):
    return EngineConfig(model=model, opt=make_optimizer(OPT),
                        local_steps=local_steps, batch_size=8, lr=2e-3,
                        aggregation="bso", n_clusters=n_clusters,
                        p1=0.9, p2=0.8, kmeans_iters=10)


def _swarm(rounds=2, local_steps=2, n_clusters=3):
    return SwarmConfig(n_clients=N_CLIENTS, n_clusters=n_clusters,
                      rounds=rounds, local_steps=local_steps,
                      kmeans_iters=10)


def _params_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ----------------------------------------------------- one-program property


def test_churn_smoke_one_program(dr_clients, dr_model):
    """Fail-fast stage for test.sh: the dropout x stale-decay churn
    grid lowers to ONE executable, runs 2 rounds with finite metrics,
    per-round presence in the metrics and staleness in the state."""
    cfg = _cfg(dr_model)
    data = make_swarm_data(dr_model.cfg, dr_clients)
    specs = grid_axes(**CHURN_AXES)
    G = len(specs)
    keys = jax.random.split(jax.random.PRNGKey(0), G)
    states = make_grid_state(dr_model, cfg.opt, dr_clients, keys)
    grid = make_grid_config(cfg, N_CLIENTS, specs)

    lowered = jax.jit(run_grid, static_argnames=("cfg", "rounds")).lower(
        states, data, cfg, grid, 2)
    compiled = lowered.compile()
    s, ms = compiled(states, data, grid)

    assert np.isfinite(np.asarray(ms.mean_val_acc)).all()
    assert np.isfinite(np.asarray(ms.train_loss)).all()
    present = np.asarray(ms.present)
    assert present.shape == (G, 2, N_CLIENTS) and present.dtype == bool
    # dropout=0 rows are always fully present
    drops = np.asarray([sp["dropout"] for sp in specs])
    assert present[drops == 0.0].all()
    stale = np.asarray(s.staleness)
    assert stale.shape == (G, N_CLIENTS) and (stale >= 0).all()
    # staleness is exactly the run length of trailing absences
    last = present[:, -1]
    assert ((stale == 0) == last).all()

    # module entry point: cache hit on re-dispatch, no recompiles
    states = make_grid_state(dr_model, cfg.opt, dr_clients, keys)
    n0 = jit_run_grid._cache_size()
    s2, _ = jit_run_grid(states, data, cfg, grid, 2)
    assert jit_run_grid._cache_size() <= n0 + 1
    n1 = jit_run_grid._cache_size()
    jit_run_grid(jax.tree.map(jnp.copy, s2), data, cfg, grid, 2)
    assert jit_run_grid._cache_size() == n1, "churn grid recompiled"


# ----------------------------------------------------- the bitwise anchor


def test_allones_churn_bitwise_plain(dr_clients, dr_model):
    """The parity contract the whole axis hangs off: ``dropout=0.0``
    (and an explicit all-ones mask) reproduce the churn-free program
    bitwise — params, opt state, losses, accuracies, assignments."""
    cfg = _cfg(dr_model)
    data = make_swarm_data(dr_model.cfg, dr_clients)
    runs = {}
    for name, churn in [
            ("plain", None),
            ("dropout0", churn_params(dropout=0.0)),
            ("ones", churn_params(mask=np.ones(N_CLIENTS, bool)))]:
        state = make_swarm_state(dr_model, cfg.opt, dr_clients,
                                 jax.random.PRNGKey(0))
        runs[name] = jit_run_rounds(state, data, cfg, 3, None, churn)
    s0, m0 = runs["plain"]
    for name in ("dropout0", "ones"):
        s, m = runs[name]
        _params_equal(s0.params, s.params)
        _params_equal(s0.opt_state, s.opt_state)
        np.testing.assert_array_equal(np.asarray(m0.train_loss),
                                      np.asarray(m.train_loss))
        np.testing.assert_array_equal(np.asarray(m0.mean_val_acc),
                                      np.asarray(m.mean_val_acc))
        np.testing.assert_array_equal(np.asarray(m0.assignments),
                                      np.asarray(m.assignments))
        assert np.asarray(m.present).all()
        assert (np.asarray(s.staleness) == 0).all()


def test_churn_grid_rows_match_serial_oracle(dr_clients, dr_model):
    """Row g of the ONE vmapped churn-grid program == the serial
    ``run_grid_point`` slice with the same key — bitwise final params,
    equal accuracies (the grid-vs-serial contract of tests/test_grid.py
    extended to the churn axes)."""
    swarm = _swarm()
    key = jax.random.PRNGKey(42)
    results, grid_run = run_grid_table(dr_model, dr_clients, swarm, OPT,
                                       key, axes=CHURN_AXES, batch_size=8)
    specs = grid_axes(**CHURN_AXES)
    keys = sweep_keys(key, specs)
    for g, spec in enumerate(specs):
        acc, run = run_grid_point(spec, dr_model, dr_clients, swarm, OPT,
                                  keys[g], batch_size=8)
        _params_equal(jax.tree.map(lambda x: x[g], grid_run.state.params),
                      run.state.params)
        assert results[g]["acc"] == acc
        np.testing.assert_array_equal(
            np.asarray(grid_run.metrics.present)[g],
            np.asarray(run.metrics.present))


# ------------------------------------------------------- churn semantics


def test_single_client_present_round(dr_clients, dr_model):
    """A round where only one client participates: the present client
    trains (params move), every absent client is frozen BITWISE (masked
    no-op local phase, no Eq. 2 receive), and nothing is NaN."""
    cfg = _cfg(dr_model)
    data = make_swarm_data(dr_model.cfg, dr_clients)
    mask = np.zeros((1, N_CLIENTS), bool)
    mask[0, 3] = True
    state = make_swarm_state(dr_model, cfg.opt, dr_clients,
                             jax.random.PRNGKey(7))
    p_before = jax.tree.map(jnp.copy, state.params)
    s, ms = jit_run_rounds(state, data, cfg, 1, None,
                           churn_params(mask=mask))
    moved = False
    for x, y in zip(jax.tree.leaves(p_before), jax.tree.leaves(s.params)):
        x, y = np.asarray(x), np.asarray(y)
        assert np.isfinite(y).all()
        np.testing.assert_array_equal(x[~mask[0]], y[~mask[0]])
        moved |= not np.array_equal(x[3], y[3])
    assert moved, "the present client never trained"
    np.testing.assert_array_equal(np.asarray(ms.present)[0], mask[0])
    np.testing.assert_array_equal(np.asarray(s.staleness),
                                  np.where(mask[0], 0, 1))


def test_staleness_resets_on_participation(dr_clients, dr_model):
    """Staleness follows the recurrence ``where(present, 0, s+1)``
    under an explicit (rounds, N) schedule — resets the round a client
    comes back, accrues while it is away."""
    cfg = _cfg(dr_model)
    data = make_swarm_data(dr_model.cfg, dr_clients)
    rng = np.random.default_rng(5)
    sched = rng.random((4, N_CLIENTS)) > 0.4
    sched[:, 0] = True          # one always-on client anchors Eq. 2
    state = make_swarm_state(dr_model, cfg.opt, dr_clients,
                             jax.random.PRNGKey(1))
    s, ms = jit_run_rounds(state, data, cfg, 4, None,
                           churn_params(stale_decay=0.5, mask=sched))
    np.testing.assert_array_equal(np.asarray(ms.present), sched)
    expect = np.zeros(N_CLIENTS, np.int64)
    for r in range(4):
        expect = np.where(sched[r], 0, expect + 1)
    np.testing.assert_array_equal(np.asarray(s.staleness), expect)
    assert np.isfinite(np.asarray(ms.mean_val_acc)).all()


def test_masked_fedavg_all_absent_cluster():
    """The masked Eq. 2 guard: a cluster whose every member is absent
    aggregates nothing — its members keep their own params bitwise, no
    NaN from the zero total — and with all-ones presence the masked
    variant is BITWISE ``cluster_fedavg``."""
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(6, 4)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(6, 3, 2)), jnp.float32)}
    assignments = jnp.asarray([0, 0, 1, 1, 2, 2], jnp.int32)
    n = jnp.asarray([10., 20., 30., 40., 50., 60.])
    # cluster 1 entirely absent (hard mask -> zero weights)
    present = jnp.asarray([1, 1, 0, 0, 1, 1], bool)
    w = n * present.astype(jnp.float32)
    out = cluster_fedavg_masked(params, assignments, w, present, k=3)
    for kk in params:
        o = np.asarray(out[kk])
        assert np.isfinite(o).all()
        # absent members of the dead cluster keep their own params
        np.testing.assert_array_equal(o[2:4], np.asarray(params[kk])[2:4])
        # live clusters aggregate normally (members agree pairwise)
        np.testing.assert_array_equal(o[0], o[1])
        np.testing.assert_array_equal(o[4], o[5])
    # all-ones bitwise anchor
    ones = jnp.ones(6, bool)
    ref = cluster_fedavg(params, assignments, n, k=3)
    got = cluster_fedavg_masked(params, assignments, n * 1.0, ones, k=3)
    for kk in params:
        np.testing.assert_array_equal(np.asarray(ref[kk]),
                                      np.asarray(got[kk]))


def test_masked_kmeans_all_absent_cluster_reseeds():
    """A cluster that captures only absent points counts as EMPTY and
    rides the existing far-point reseed, restricted to present
    candidates; with an all-ones mask the masked k-means is bitwise the
    unmasked run."""
    # two tight groups far apart; the second group is entirely absent
    rng = np.random.default_rng(3)
    X = np.concatenate([rng.normal(0.0, .1, size=(6, 2)),
                        rng.normal(50.0, .1, size=(4, 2))]).astype(np.float32)
    mask = np.asarray([True] * 6 + [False] * 4)
    C = np.asarray([[0.0, 0.0], [50.0, 50.0]], np.float32)  # c1 -> absent
    newC = np.asarray(lloyd_step(jnp.asarray(X), jnp.asarray(C), 2,
                                 mask=jnp.asarray(mask)))
    assert np.isfinite(newC).all()
    # the reseeded centroid is a PRESENT point, not an absent one
    d_present = np.linalg.norm(X[:6] - newC[1], axis=1).min()
    d_absent = np.linalg.norm(X[6:] - newC[1], axis=1).min()
    assert d_present == 0.0 and d_absent > 1.0
    # all-ones mask == unmasked, bitwise
    key = jax.random.PRNGKey(0)
    C_ref, a_ref = kmeans(key, jnp.asarray(X), 3, iters=5)
    C_m, a_m = kmeans(key, jnp.asarray(X), 3, iters=5,
                      mask=jnp.ones(len(X), bool))
    np.testing.assert_array_equal(np.asarray(C_ref), np.asarray(C_m))
    np.testing.assert_array_equal(np.asarray(a_ref), np.asarray(a_m))


def test_churn_validation_errors(dr_clients, dr_model):
    """Construction-time guards: churn grids refuse the sorted
    local-steps schedule, mixed churn/non-churn grids must be made
    explicit, and churn_params validates its ranges."""
    cfg = _cfg(dr_model)
    data = make_swarm_data(dr_model.cfg, dr_clients)
    with pytest.raises(ValueError):
        churn_params(dropout=1.5)
    with pytest.raises(ValueError):
        churn_params(stale_decay=-0.1)
    with pytest.raises(ValueError):
        make_grid_config(cfg, N_CLIENTS, [{"dropout": 0.3}, {"k": 2}])
    grid = make_grid_config(cfg, N_CLIENTS,
                            [{"dropout": 0.0}, {"dropout": 0.3}])
    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    states = make_grid_state(dr_model, cfg.opt, dr_clients, keys)
    # the message must stay actionable: name the unsupported combination
    # AND the remedy (schedule=None → the masked path)
    with pytest.raises(ValueError,
                       match="sorted local-steps schedule does not support "
                             "churn rows"):
        run_grid(states, data, cfg, grid, 2,
                 schedule=((0, 1), jnp.asarray([2, 2])))
    with pytest.raises(ValueError, match="pass schedule=None"):
        run_grid(states, data, cfg, grid, 2, schedule=(2, 2))


def test_dropout0_grid_row_bitwise_matches_churnfree_fit(dr_clients,
                                                         dr_model):
    """Post-hier regression guard composing the two pinned contracts —
    grid row g == serial ``run_rounds`` with the same key, and
    dropout=0 churn == churn-free — end to end: the dropout=0 row of a
    churn grid reproduces the plain churn-free ``jit_run_rounds`` fit
    BITWISE (params, losses, accuracies)."""
    cfg = _cfg(dr_model)
    data = make_swarm_data(dr_model.cfg, dr_clients)
    keys = jax.random.split(jax.random.PRNGKey(3), 2)
    states = make_grid_state(dr_model, cfg.opt, dr_clients, keys)
    grid = make_grid_config(cfg, N_CLIENTS,
                            [{"dropout": 0.0}, {"dropout": 0.3}])
    gs, gm = jit_run_grid(states, data, cfg, grid, 2)

    state0 = make_swarm_state(dr_model, cfg.opt, dr_clients, keys[0])
    s0, m0 = jit_run_rounds(state0, data, cfg, 2)
    _params_equal(jax.tree.map(lambda x: x[0], gs.params), s0.params)
    np.testing.assert_array_equal(np.asarray(gm.train_loss)[0],
                                  np.asarray(m0.train_loss))
    np.testing.assert_array_equal(np.asarray(gm.mean_val_acc)[0],
                                  np.asarray(m0.mean_val_acc))
    assert np.asarray(gm.present)[0].all()


# ---------------------------------------------------------- fleet regime


def test_fleet_allones_churn_program_bitwise(dr_model, dr_clients):
    """The churn-program driver with every fault knob off except the
    (always-met) quorum is BITWISE the churn-free driver: same stats,
    accuracies, decisions, losses — one executable each."""
    mesh = make_fleet_mesh(N_CLIENTS)
    kw = dict(rounds=2, local_steps=2, batch_size=8, seed=0)
    opt = make_optimizer(OPT)
    res = run_fleet(dr_model, opt, mesh, dr_clients, **kw)
    res_c = run_fleet(dr_model, make_optimizer(OPT), mesh, dr_clients,
                      faults=FleetFaults(quorum=1), **kw)
    assert res.n_compiles == 1 and res_c.n_compiles == 1
    for a, b in zip(res.history, res_c.history):
        np.testing.assert_array_equal(a.stats, b.stats)
        np.testing.assert_array_equal(a.val_acc, b.val_acc)
        np.testing.assert_array_equal(a.assignments, b.assignments)
        assert a.train_loss == b.train_loss
        assert b.coordinated and b.present.all() and b.reported.all()
    _params_equal(res.params, res_c.params)


def test_fleet_quorum_determinism(dr_model, dr_clients):
    """The fault-injected driver replays bit-for-bit, quorum-missed
    rounds re-apply the previous decision, and coordinated rounds are
    exactly ``host_coordinator`` on the effective (last-seen-filled)
    stats the log lets us reconstruct."""
    mesh = make_fleet_mesh(N_CLIENTS)
    fa = FleetFaults(drop_rate=0.4, straggler_rate=0.3, delay_s=1.0,
                     stale_decay=0.5, quorum=5)
    kw = dict(rounds=4, local_steps=2, batch_size=8, seed=0, faults=fa)
    res = run_fleet(dr_model, make_optimizer(OPT), mesh, dr_clients, **kw)
    res2 = run_fleet(dr_model, make_optimizer(OPT), mesh, dr_clients, **kw)
    assert res.n_compiles == 1
    assert any(not log.coordinated for log in res.history) or \
        all(log.reported.sum() >= fa.quorum for log in res.history)

    last_stats = np.zeros_like(res.history[0].stats)
    last_val = np.zeros(N_CLIENTS, np.float32)
    have = np.zeros(N_CLIENTS, bool)
    prev_assign = np.arange(N_CLIENTS, dtype=np.int32)
    for r, (log, log2) in enumerate(zip(res.history, res2.history)):
        # replay determinism
        np.testing.assert_array_equal(log.assignments, log2.assignments)
        np.testing.assert_array_equal(log.val_acc, log2.val_acc)
        assert log.coordinated == log2.coordinated
        # the fault draw is the documented pure function
        present, straggler = draw_faults(fa, N_CLIENTS, 0, r)
        np.testing.assert_array_equal(log.present, present)
        np.testing.assert_array_equal(log.reported, present & ~straggler)
        assert log.sim_delay_s == (fa.delay_s if straggler.any() else 0.0)
        # reconstruct the coordinator's view and replay its decision
        stats_eff, val_eff = log.stats.copy(), log.val_acc.copy()
        miss = ~log.reported & have
        stats_eff[miss] = last_stats[miss]
        val_eff[miss] = last_val[miss]
        if log.coordinated:
            a, c, _ = host_coordinator(stats_eff, val_eff, k=3, p1=0.9,
                                       p2=0.8, seed=0, round_idx=r)
            np.testing.assert_array_equal(log.assignments, a)
            np.testing.assert_array_equal(log.centers, c)
        else:
            assert log.reported.sum() < fa.quorum
            np.testing.assert_array_equal(log.assignments, prev_assign)
        last_stats[log.reported] = log.stats[log.reported]
        last_val[log.reported] = log.val_acc[log.reported]
        have |= log.reported
        prev_assign = log.assignments


def test_fleet_ckpt_periodic_equals_final(dr_model, dr_clients, tmp_path):
    """Satellite bugfix 1: when ``ckpt_every`` divides ``rounds``, the
    last periodic export ``_r{rounds}`` is BITWISE the final export —
    the ``r != rounds - 1`` skip is gone."""
    mesh = make_fleet_mesh(N_CLIENTS)
    ck = str(tmp_path / "ck")
    run_fleet(dr_model, make_optimizer(OPT), mesh, dr_clients, rounds=2,
              local_steps=2, batch_size=8, seed=0, ckpt_path=ck,
              ckpt_every=1)
    final = np.load(ck + ".npz")
    last = np.load(ck + "_r2.npz")
    assert set(final.files) == set(last.files)
    for kk in final.files:
        np.testing.assert_array_equal(final[kk], last[kk])
    m_final = json.loads((tmp_path / "ck.json").read_text())
    m_last = json.loads((tmp_path / "ck_r2.json").read_text())
    assert m_final["step"] == m_last["step"] == 2
    # intermediate export exists too
    assert (tmp_path / "ck_r1.npz").exists()


def test_fleet_rounds0_ckpt_warns_and_exports(dr_model, dr_clients,
                                              tmp_path):
    """Satellite bugfix 2: ``rounds=0`` with a ckpt_path used to skip
    the export silently; it now warns and saves the initial swarm under
    the identity Eq. 2."""
    mesh = make_fleet_mesh(N_CLIENTS)
    ck = str(tmp_path / "zero")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        res = run_fleet(dr_model, make_optimizer(OPT), mesh, dr_clients,
                        rounds=0, seed=0, ckpt_path=ck)
    assert any("rounds=0" in str(x.message) for x in w)
    assert (tmp_path / "zero.npz").exists()
    man = json.loads((tmp_path / "zero.json").read_text())
    assert man["step"] == 0
    assert man["extra"]["n_clients"] == N_CLIENTS
    assert res.history == []
