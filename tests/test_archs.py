"""Per-assigned-architecture smoke tests (deliverable f): a REDUCED
same-family variant (<=2 layers, d_model<=128, <=4 experts) runs one
forward + one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.base import OptimizerConfig
from repro.models import build_model
from repro.optim.optimizers import make_optimizer
from repro.train.steps import make_train_step

KEY = jax.random.PRNGKey(0)


def _smoke_batch(cfg, B=2, S=24):
    if cfg.family == "encdec":
        S_dec = 12
        return {
            "audio_embed": jax.random.normal(KEY, (B, 32, cfg.d_model)) * 0.02,
            "tokens": jax.random.randint(KEY, (B, S_dec), 0, cfg.vocab_size),
            "labels": jax.random.randint(KEY, (B, S_dec), 0, cfg.vocab_size),
        }, (B, S_dec, cfg.vocab_size)
    batch = {
        "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["vision_embed"] = jax.random.normal(
            KEY, (B, cfg.n_vision_tokens, cfg.d_model)) * 0.02
    return batch, (B, S, cfg.vocab_size)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).smoke()
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    model = build_model(cfg)
    params = model.init(KEY)

    batch, logits_shape = _smoke_batch(cfg)
    logits, aux = model.forward(params, batch)
    assert logits.shape == logits_shape, (logits.shape, logits_shape)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    opt = make_optimizer(OptimizerConfig(name="adamw", lr=1e-3))
    state = opt.init(params)
    step = make_train_step(model, opt)
    new_params, _, metrics = step(params, state, batch, jnp.asarray(1e-3))
    assert np.isfinite(float(metrics["loss"]))
    # parameters actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", [a for a in ASSIGNED_ARCHS
                                  if a != "whisper-base"])
def test_smoke_decode_step(arch):
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params = model.init(KEY)
    cache = model.init_cache(2, 16)
    logits, new_cache = model.decode_step(
        params, jnp.ones((2, 1), jnp.int32), cache, jnp.asarray(3, jnp.int32))
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


def test_whisper_decode_step():
    cfg = get_config("whisper-base").smoke()
    model = build_model(cfg)
    params = model.init(KEY)
    cache = model.init_cache(2, 16)
    logits, _ = model.decode_step(params, jnp.ones((2, 1), jnp.int32), cache,
                                  jnp.asarray(3, jnp.int32))
    assert logits.shape == (2, 1, cfg.vocab_size)


def test_exact_assigned_configs():
    """The full configs must match the assignment table exactly."""
    expect = {
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
    }
    for arch, (L, d, H, KV, ff, V) in expect.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L and cfg.d_model == d
        assert cfg.n_heads == H and cfg.n_kv_heads == KV
        assert cfg.d_ff == ff and cfg.vocab_size == V
    assert get_config("zamba2-1.2b").ssm_state == 64
    assert get_config("mamba2-370m").ssm_state == 128
    assert get_config("kimi-k2-1t-a32b").n_experts == 384
    assert get_config("kimi-k2-1t-a32b").top_k == 8
    assert get_config("llama4-maverick-400b-a17b").n_experts == 128
    assert get_config("llama4-maverick-400b-a17b").top_k == 1


def test_param_counts_in_expected_range():
    """Full-config parameter counts (eval_shape only, no allocation)
    should land near each model card's nameplate."""
    expect = {
        "granite-3-2b": (2e9, 4e9),
        "command-r-35b": (30e9, 40e9),
        "deepseek-67b": (60e9, 72e9),
        "kimi-k2-1t-a32b": (0.9e12, 1.15e12),
        "llama4-maverick-400b-a17b": (250e9, 450e9),
        "mamba2-370m": (0.3e9, 0.45e9),
        "deepseek-7b": (6e9, 8e9),
        "internvl2-26b": (18e9, 26e9),
    }
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        model = build_model(cfg)
        params = jax.eval_shape(lambda m=model: m.init(jax.random.PRNGKey(0)))
        n = sum(int(x.size) for x in jax.tree.leaves(params))
        assert lo <= n <= hi, f"{arch}: {n:,} not in [{lo:,.0f}, {hi:,.0f}]"
