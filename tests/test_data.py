"""Data pipeline: Table-I exactness, splits, determinism, non-IID."""
import warnings

import numpy as np
import pytest

from repro.data.dr import (TABLE_I, bucket_clients, make_dr_swarm_data,
                           scale_table)
from repro.data.tokens import make_token_swarm_data, sample_tokens


def test_table_1_matches_paper():
    assert TABLE_I.shape == (5, 14)
    assert int(TABLE_I.sum()) == 3657
    np.testing.assert_array_equal(TABLE_I.sum(axis=0),
                                  [410, 638, 974, 351, 141, 533, 287, 92, 61,
                                   52, 42, 34, 28, 14])
    # spot checks straight from the paper's table
    assert TABLE_I[2, 0] == 307      # C1 Moderate
    assert TABLE_I[0, 3] == 351      # C4 NoDR only
    assert TABLE_I[2, 3] == 0        # C4 has no Moderate
    assert TABLE_I[2, 13] == 0       # C14 has no Moderate
    assert TABLE_I[0, 2] == 901      # C3 NoDR-heavy


def test_scale_table_minimum_counts_clamp_and_warn():
    """Large --data-scale must clamp (never drop) nonzero cells, keep
    zero cells zero, and WARN that the floor distorts class balance —
    the silent-distortion fix for the table benchmarks."""
    with pytest.warns(RuntimeWarning, match="min_count"):
        t = scale_table(64)
    assert (t[TABLE_I > 0] >= 2).all()
    assert (t[TABLE_I == 0] == 0).all()
    # the un-clamped region still scales
    big = TABLE_I >= 128
    assert (t[big] == TABLE_I[big] // 64).all()

    # scale 1 is Table I exactly, silently
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        np.testing.assert_array_equal(scale_table(1), TABLE_I)

    with pytest.raises(ValueError):
        scale_table(0)

    # the floored table still yields well-formed clinics: every split
    # non-empty even where a clinic's total is a handful of rows
    clinics = make_dr_swarm_data(image_size=8, seed=0, table=t)
    for clinic in clinics:
        assert clinic["n_train"] >= 1
        assert len(clinic["val"][1]) >= 1 and len(clinic["test"][1]) >= 1


def test_dr_dataset_counts_and_splits():
    small = np.maximum(TABLE_I // 16, (TABLE_I > 0).astype(np.int64))
    clinics = make_dr_swarm_data(image_size=8, seed=0, table=small)
    assert len(clinics) == 14
    for c, clinic in enumerate(clinics):
        n_total = int(small[:, c].sum())
        n_train = len(clinic["train"][1])
        assert n_train == clinic["n_train"]
        assert abs(n_train - 0.8 * n_total) <= max(2, 0.1 * n_total)
        assert len(clinic["val"][1]) >= 1 and len(clinic["test"][1]) >= 1
        X = clinic["train"][0]
        assert X.dtype == np.float32 and X.min() >= 0 and X.max() <= 1


def test_dr_dataset_deterministic():
    small = np.maximum(TABLE_I // 32, (TABLE_I > 0).astype(np.int64))
    a = make_dr_swarm_data(image_size=8, seed=7, table=small)
    b = make_dr_swarm_data(image_size=8, seed=7, table=small)
    np.testing.assert_array_equal(a[0]["train"][0], b[0]["train"][0])


def test_dr_images_class_separable():
    """Higher grades must carry more bright-lesion signal (the learnable
    structure the synthetic generator injects)."""
    small = np.ones_like(TABLE_I)      # every clinic non-empty
    small[0, 0] = 30
    small[4, 0] = 30
    clinics = make_dr_swarm_data(image_size=16, seed=0, table=small)
    X, y = clinics[0]["train"]
    mean0 = X[y == 0].mean()
    mean4 = X[y == 4].mean()
    assert mean4 > mean0 + 0.01


def test_bucket_clients_pow2_grouping():
    """Power-of-two ceilings group clients; exact powers stay in their
    own ceiling; the result partitions range(N) ascending per bucket."""
    sizes = [3, 4, 5, 8, 9, 16]        # ceilings 4, 4, 8, 8, 16, 16
    groups = bucket_clients(sizes, max_buckets=4)
    assert [g.tolist() for g in groups] == [[0, 1], [2, 3], [4, 5]]


def test_bucket_clients_merges_to_max_buckets():
    """More distinct ceilings than max_buckets merge adjacent groups by
    least added pad rows; the output stays a partition and is
    deterministic."""
    sizes = [1, 2, 4, 8, 16, 32, 64, 128]    # 8 distinct ceilings
    groups = bucket_clients(sizes, max_buckets=3)
    assert len(groups) == 3
    assert sorted(i for g in groups for i in g.tolist()) == list(range(8))
    again = bucket_clients(sizes, max_buckets=3)
    for a, b in zip(groups, again):
        np.testing.assert_array_equal(a, b)
    # ceilings ascend bucket to bucket (the engine's layout contract)
    maxima = [max(np.asarray(sizes)[g]) for g in groups]
    assert maxima == sorted(maxima)


def test_bucket_clients_quantile_and_edges():
    """Quantile strategy splits by size order into equal-count groups;
    degenerate inputs behave: single client, more buckets than
    clients, and invalid arguments raise."""
    groups = bucket_clients([50, 1, 30, 2, 40, 3], max_buckets=3,
                            strategy="quantile")
    assert len(groups) == 3
    assert sorted(i for g in groups for i in g.tolist()) == list(range(6))
    assert [g.tolist() for g in bucket_clients([7])] == [[0]]
    assert len(bucket_clients([5, 6], max_buckets=10,
                              strategy="quantile")) <= 2
    with pytest.raises(ValueError):
        bucket_clients([])
    with pytest.raises(ValueError):
        bucket_clients([1, 2], max_buckets=0)
    with pytest.raises(ValueError):
        bucket_clients([1, 2], strategy="nope")


def test_token_clients_are_non_iid():
    clients = make_token_swarm_data(3, vocab=64, n_seqs=8, seq_len=128)
    def bigram_mass(toks):
        h = np.zeros((64, 64))
        for row in toks:
            for a, b in zip(row[:-1], row[1:]):
                h[a, b] += 1
        return h / h.sum()
    h0 = bigram_mass(clients[0]["train"][0])
    h1 = bigram_mass(clients[1]["train"][0])
    assert np.abs(h0 - h1).sum() > 0.5       # very different transition maps


def test_tokens_deterministic():
    a = sample_tokens(32, 4, 16, client=1, seed=3)
    b = sample_tokens(32, 4, 16, client=1, seed=3)
    np.testing.assert_array_equal(a, b)
