"""Sharding-rule unit tests (run on the 1-device CPU mesh by building
PartitionSpecs only — no allocation against big meshes)."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import (build_param_specs,
                                  logical_axes_for_path, spec_for)


class FakeMesh:
    """Shape-only stand-in so tests can reason about 16x16 without
    building 256 devices."""
    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_logical_axes_for_known_paths():
    assert logical_axes_for_path("embedding/table", 2) == ("p_vocab", "p_embed")
    assert logical_axes_for_path("blocks/0/attn/wq", 2) == ("p_embed", "p_heads")
    assert logical_axes_for_path("blocks/3/mlp/wo", 2) == ("p_mlp", "p_embed")
    assert logical_axes_for_path("moe/experts/wi", 3) == \
        ("p_experts", "p_embed", "p_mlp")
    # stacked (scanned) variant gets a leading layers axis
    assert logical_axes_for_path("layers/period0/attn/wq", 3) == \
        ("layers", "p_embed", "p_heads")
    # adafactor factored states inherit parent axes
    assert logical_axes_for_path("v/blocks/0/mlp/wi/vr", 1) == ("p_embed",)
    assert logical_axes_for_path("v/blocks/0/mlp/wi/vc", 1) == ("p_mlp",)


def test_spec_divisibility_fallback():
    # 8 kv heads cannot shard over model=16 -> unsharded
    spec = spec_for(("p_embed", "p_kv"), MESH, (2048, 8 * 128))
    assert spec == P("data", "model")     # 1024 % 16 == 0 fine
    spec = spec_for(("p_kv",), MESH, (8,))
    assert spec == P(None)


def test_spec_never_reuses_mesh_axis():
    spec = spec_for(("cache_seq", "act_heads"), MESH, (32768, 64))
    flat = []
    for part in spec:
        if part is None:
            continue
        flat.extend(part if isinstance(part, tuple) else [part])
    assert len(flat) == len(set(flat))


def test_cache_seq_takes_both_axes_when_batch_is_one():
    # long_500k: batch 1 frees "data"; cache seq shards 256-way
    spec = spec_for(("batch", "cache_seq", "p_kv", None), MESH,
                    (1, 524288, 8, 128))
    assert spec[0] is None
    assert spec[1] == ("data", "model")


def test_build_param_specs_on_real_smoke_model():
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("granite-3-2b").smoke()
    model = build_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = build_param_specs(params, MESH)
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert all(isinstance(s, P) for s in flat)


def test_multipod_fsdp_uses_pod_axis():
    spec = spec_for(("p_embed", "p_mlp"), MESH3, (8192, 22528))
    # p_embed -> data then pod (8192 % (16*2) == 0)
    assert spec[0] == ("data", "pod")
    assert spec[1] == "model"


def test_shard_act_noop_without_context():
    from repro.sharding import shard_act
    x = jnp.ones((4, 8))
    y = shard_act(x, "batch", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_shard_act_applies_constraint_under_mesh():
    from repro.sharding import shard_act, use_sharding
    mesh = jax.make_mesh((1,), ("data",))

    @jax.jit
    def f(x):
        return shard_act(x, "batch", None) * 2

    with mesh, use_sharding(mesh):
        out = f(jnp.ones((4, 8)))
    np.testing.assert_array_equal(np.asarray(out), 2 * np.ones((4, 8)))
