"""Launch-layer unit tests: the dry-run's cost instrumentation, the
microbatch divisibility guard (§Perf H4), profiles, and the e2e
training driver at miniature scale.

NOTE: these import repro.launch.dryrun, which sets XLA_FLAGS for 512
host devices — harmless here because jax is already initialised with
1 device by earlier imports in the pytest process; nothing in these
tests builds the production mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np


def _dryrun():
    from repro.launch import dryrun
    return dryrun


def test_collective_bytes_parser():
    hlo = """
  %ag = bf16[128,256]{1,0} all-gather(bf16[8,256]{1,0} %x), dims={0}
  %ar.1 = f32[1024]{0} all-reduce(f32[1024]{0} %y), to_apply=%add
  %tup = (f32[8]{0}, f32[8]{0}) all-to-all(f32[8]{0} %a, f32[8]{0} %b)
  %cp = u32[4]{0} collective-permute(u32[4]{0} %c)
  %not_a_collective = f32[999]{0} add(f32[999]{0} %p, f32[999]{0} %q)
"""
    out = _dryrun().collective_bytes(hlo)
    assert out["all-gather"] == 128 * 256 * 2
    assert out["all-reduce"] == 1024 * 4
    assert out["all-to-all"] == 2 * 8 * 4
    assert out["collective-permute"] == 4 * 4
    assert out["total"] == sum(out[k] for k in
                               ("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute"))


def test_microbatch_divisibility_guard():
    dr = _dryrun()
    from repro.configs import INPUT_SHAPES, get_config
    cfg = get_config("granite-3-2b")
    shape = INPUT_SHAPES["train_4k"]          # B=256
    assert dr.microbatches_for(cfg, shape, n_dp=16) == 16   # 256/16=16 % 16 ok
    assert dr.microbatches_for(cfg, shape, n_dp=32) == 8    # backs off (H4)
    assert dr.microbatches_for(cfg, INPUT_SHAPES["decode_32k"], n_dp=16) == 0


def test_optimized_profile_applies_kept_variants():
    dr = _dryrun()
    from repro.configs import INPUT_SHAPES
    kimi = dr.runtime_config("kimi-k2-1t-a32b", INPUT_SHAPES["train_4k"],
                             optimized=True)
    assert kimi.moe_grouped_dispatch            # H1
    granite = dr.runtime_config("granite-3-2b", INPUT_SHAPES["prefill_32k"],
                                optimized=True)
    assert granite.vocab_round_to == 128        # H2 (49155 % 128 != 0)
    assert granite.attn_chunk_q == 256
    ds = dr.runtime_config("deepseek-7b", INPUT_SHAPES["decode_32k"],
                           optimized=True)
    assert ds.cache_dtype == "float8_e4m3fn"    # H3
    mamba = dr.runtime_config("mamba2-370m", INPUT_SHAPES["decode_32k"],
                              optimized=True)
    assert mamba.cache_dtype == ""              # attention-free: no KV cache
    base = dr.runtime_config("kimi-k2-1t-a32b", INPUT_SHAPES["train_4k"])
    assert not base.moe_grouped_dispatch        # baseline stays faithful


def test_long_500k_runtime_policy():
    dr = _dryrun()
    from repro.configs import INPUT_SHAPES
    dense = dr.runtime_config("command-r-35b", INPUT_SHAPES["long_500k"])
    assert dense.sliding_window == 8192         # documented serving variant
    ssm = dr.runtime_config("mamba2-370m", INPUT_SHAPES["long_500k"])
    assert ssm.sliding_window == 0              # native O(1) state
    assert not dr.shape_applicable("whisper-base", "long_500k")


def test_probe_layer_points():
    dr = _dryrun()
    from repro.configs import get_config
    assert dr._probe_layers(get_config("granite-3-2b")) == (1, 2)
    assert dr._probe_layers(get_config("kimi-k2-1t-a32b")) == (2, 3)   # 1 dense prefix
    assert dr._probe_layers(get_config("llama4-maverick-400b-a17b")) == (2, 4)
    assert dr._probe_layers(get_config("zamba2-1.2b")) == (6, 12)


def test_run_single_descends():
    """Miniature end-to-end run of the training driver."""
    import argparse
    from repro.launch.train import run_single
    ns = argparse.Namespace(preset="tiny", steps=40, batch=8, seq=32,
                            lr=5e-3, seed=0, ckpt="")
    final_ce = run_single(ns)
    assert final_ce < 6.2       # ln(512)=6.24 — beats uniform within 40 steps


def test_fleet_round_trains_on_per_step_microbatches():
    """Regression: the fleet-round local loop re-trained on the
    identical batch every local step. With n_local_steps=2 the round
    must equal two sequential steps on the batch's two *distinct*
    halves. (The fleet round is now built on the shared engine body and
    additionally returns the in-program distribution-stat upload.)"""
    from repro.configs import get_config
    from repro.configs.base import OptimizerConfig
    from repro.core.engine import make_fleet_round
    from repro.models import build_model
    from repro.optim.optimizers import make_optimizer
    from repro.train.steps import make_train_step

    cfg = get_config("granite-3-2b").smoke()
    model = build_model(cfg)
    opt = make_optimizer(OptimizerConfig(name="adam", lr=1e-2))
    round_step = make_fleet_round(model, opt, k=1, n_local_steps=2)

    params = model.init(jax.random.PRNGKey(0))
    B, S = 4, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    sparams = jax.tree.map(lambda x: x[None], params)
    sopt = jax.vmap(opt.init)(sparams)
    out_p, _, stats = jax.jit(round_step)(
        sparams, sopt, batch, jnp.float32(1e-2),
        jnp.zeros((1,), jnp.int32), jnp.ones((1,), jnp.float32))
    assert stats.shape[0] == 1 and stats.ndim == 2

    step = make_train_step(model, opt)
    p, o = params, opt.init(params)
    for half in (slice(0, 2), slice(2, 4)):
        hb = {k: v[0, half] for k, v in batch.items()}
        p, o, _ = step(p, o, hb, jnp.float32(1e-2))

    # adam's rsqrt amplifies vmap/jit reassociation noise to ~4e-4; the
    # old bug (same batch twice) is two orders of magnitude away (~4e-2)
    got = jax.tree.leaves(jax.tree.map(lambda x: x[0], out_p))
    for g, w in zip(got, jax.tree.leaves(p)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-2, atol=2e-3)

    p2, o2 = params, opt.init(params)
    full = {k: v[0] for k, v in batch.items()}
    for _ in range(2):
        p2, o2, _ = step(p2, o2, full, jnp.float32(1e-2))
    bug_gap = max(float(jnp.abs(g - w).max())
                  for g, w in zip(got, jax.tree.leaves(p2)))
    assert bug_gap > 1e-2, bug_gap


def test_serve_prefill_cache_matches_forward():
    """serve.prefill_into_cache must leave the cache in the same state a
    teacher-forced forward would produce (greedy next tokens agree)."""
    from repro.configs import get_config
    from repro.models import build_model
    from repro.launch.serve import prefill_into_cache
    cfg = get_config("granite-3-2b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    P = 6
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, P), 0, cfg.vocab_size)
    last_tok, cache = prefill_into_cache(model, params, prompts, model.init_cache(2, P + 2))
    logits, _ = model.forward(params, {"tokens": prompts})
    expect = jnp.argmax(logits[:, -1, :], axis=-1)
    np.testing.assert_array_equal(np.asarray(last_tok), np.asarray(expect))
