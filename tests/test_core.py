"""BSO-SL core unit tests: distribution stats, k-means, brain storm,
cluster aggregation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import cluster_fedavg, fedavg
from repro.core.bso import brain_storm
from repro.core.diststats import (full_params_bytes, param_distribution,
                                  swarm_distribution_matrix,
                                  swarm_distribution_matrix_loop,
                                  upload_bytes)
from repro.core.kmeans import assign, kmeans, lloyd_step

KEY = jax.random.PRNGKey(0)

# jax.shard_map only exists on newer jax; fall back to the experimental
# location (the API is identical for our usage)
shard_map = getattr(jax, "shard_map", None)
if shard_map is None:
    from jax.experimental.shard_map import shard_map


# ---------------------------------------------------------------- diststats

def test_param_distribution_deterministic_order():
    p = {"b": jnp.ones((3, 3)), "a": jnp.zeros((5,)),
         "c": {"x": jnp.full((2,), 2.0)}}
    f1 = param_distribution(p)
    f2 = param_distribution({"c": {"x": jnp.full((2,), 2.0)},
                             "a": jnp.zeros((5,)), "b": jnp.ones((3, 3))})
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    # a: mean 0 var 0; b: mean 1 var 0; c/x: mean 2 var 0
    np.testing.assert_allclose(np.asarray(f1),
                               [0, 0, 1, 0, 2, 0], atol=1e-7)


def test_upload_bytes_is_tiny_vs_full_params():
    p = {"w1": jnp.zeros((256, 256)), "w2": jnp.zeros((1024,))}
    assert upload_bytes(p) == 2 * 2 * 4
    assert full_params_bytes(p) == (256 * 256 + 1024) * 4
    assert upload_bytes(p) < full_params_bytes(p) / 1000


def test_swarm_distribution_matrix_batched_matches_loop():
    """New-vs-old parity at N=8: the single-pass batched coordinator
    path equals the per-client host loop (jnp and Pallas flavours)."""
    n = 8
    ks = jax.random.split(KEY, 3)
    stacked = {"w": jax.random.normal(ks[0], (n, 5, 3)) * 3.0 + 1.0,
               "nested": {"b": jax.random.normal(ks[1], (n, 7))},
               "step": jnp.zeros((n,), jnp.int32)}        # non-float: skipped
    old = swarm_distribution_matrix_loop(stacked, n)
    new = swarm_distribution_matrix(stacked, n)
    assert new.shape == old.shape == (n, 4)
    np.testing.assert_allclose(np.asarray(new), np.asarray(old),
                               rtol=1e-5, atol=1e-6)
    new_pl = swarm_distribution_matrix(stacked, n, use_pallas=True)
    np.testing.assert_allclose(np.asarray(new_pl), np.asarray(old),
                               rtol=1e-4, atol=1e-5)


# ------------------------------------------------------------------ kmeans

def test_kmeans_separates_obvious_clusters():
    a = jax.random.normal(KEY, (10, 4)) * 0.1
    b = jax.random.normal(jax.random.PRNGKey(1), (10, 4)) * 0.1 + 10.0
    X = jnp.concatenate([a, b])
    _, assignments = kmeans(KEY, X, 2, iters=10)
    a_ids = set(np.asarray(assignments[:10]).tolist())
    b_ids = set(np.asarray(assignments[10:]).tolist())
    assert len(a_ids) == 1 and len(b_ids) == 1 and a_ids != b_ids


def test_kmeans_assign_is_nearest():
    X = jax.random.normal(KEY, (20, 3))
    C = jax.random.normal(jax.random.PRNGKey(1), (4, 3))
    a = assign(X, C)
    d = jnp.sum((X[:, None, :] - C[None]) ** 2, axis=-1)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(jnp.argmin(d, 1)))


def test_kmeans_no_empty_clusters_with_enough_points():
    X = jax.random.normal(KEY, (14, 6))
    _, a = kmeans(KEY, X, 3, iters=30)
    assert len(set(np.asarray(a).tolist())) == 3


def test_kmeans_empty_clusters_reseed_to_distinct_points():
    """Two empty clusters must take two *different* far points (the old
    reseed gave every empty cluster the same farthest point, leaving
    duplicate centroids that can never separate)."""
    X = jnp.asarray([[0.0], [1.0], [10.0], [11.0], [20.0], [21.0]])
    C = jnp.asarray([[0.5], [100.0], [200.0]])   # clusters 1 and 2 empty
    newC = np.asarray(lloyd_step(X, C, 3))
    assert newC[1, 0] != newC[2, 0]
    assert {newC[1, 0], newC[2, 0]} <= set(np.asarray(X)[:, 0].tolist())
    # the farthest two points from the only live centroid
    assert {newC[1, 0], newC[2, 0]} == {21.0, 20.0}


def test_kmeans_pallas_path_matches_jnp():
    X = jax.random.normal(KEY, (40, 6))
    C1, a1 = kmeans(KEY, X, 3, iters=8)
    C2, a2 = kmeans(KEY, X, 3, iters=8, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_allclose(np.asarray(C1), np.asarray(C2),
                               rtol=1e-6, atol=1e-6)


# -------------------------------------------------------------- brain storm

def _plan(seed, p1, p2, val=None, assignments=None, k=3, n=14):
    rng = np.random.default_rng(seed)
    val = np.linspace(0, 1, n) if val is None else val
    assignments = np.arange(n) % k if assignments is None else assignments
    return brain_storm(rng, assignments, val, k, p1, p2)


def test_centers_are_best_val_members_when_no_disruption():
    # p1 = p2 = 1.0 => r > p never fires: pure center selection
    plan = _plan(0, 1.0, 1.0)
    for c in range(3):
        members = np.where(plan.assignments == c)[0]
        best = members[np.argmax(np.linspace(0, 1, 14)[members])]
        assert plan.centers[c] == best
    assert plan.events == []


def test_replacement_fires_with_p1_zero():
    plan = _plan(3, 0.0, 1.0)
    # every cluster's center replaced by a random member (still a member)
    for c in range(3):
        members = set(np.where(plan.assignments == c)[0].tolist())
        assert int(plan.centers[c]) in members


def test_swap_exchanges_cluster_membership():
    plan = _plan(5, 1.0, 0.0)   # swaps fire every cluster
    assert any("swap" in e for e in plan.events)
    # assignments remain a permutation-consistent partition of clients
    assert sorted(np.unique(plan.assignments).tolist()) == [0, 1, 2] or \
        len(np.unique(plan.assignments)) <= 3


def test_paper_probabilities():
    """p1=0.9/p2=0.8 with r>p trigger => ~10% / ~20% event rates."""
    n_rep, n_swap = 0, 0
    trials = 2000
    for s in range(trials):
        plan = _plan(s, 0.9, 0.8)
        n_rep += sum("replace" in e for e in plan.events)
        n_swap += sum("swap" in e for e in plan.events)
    rep_rate = n_rep / (trials * 3)
    swap_rate = n_swap / (trials * 3)
    assert 0.05 < rep_rate < 0.15, rep_rate        # ~0.1 (minus no-op draws)
    assert 0.10 < swap_rate < 0.30, swap_rate      # ~0.2
    # swaps are pairwise: both clusters record one event jointly => the
    # per-cluster *initiation* rate is what we bound


def test_brain_storm_assignments_are_a_relabeling():
    """For any (p1, p2): post-swap assignments are the same multiset of
    cluster labels (swaps exchange membership, never create/destroy),
    and every center is a member of its post-swap cluster."""
    for seed in range(30):
        rng = np.random.default_rng(seed)
        n, k = 14, 3
        a0 = rng.integers(0, k, size=n)
        val = rng.uniform(size=n).astype(np.float32)
        p1, p2 = rng.uniform(), rng.uniform()
        plan = brain_storm(rng, a0.copy(), val, k, p1, p2)
        assert sorted(plan.assignments.tolist()) == sorted(a0.tolist())
        for c in range(k):
            if plan.centers[c] >= 0:
                assert plan.assignments[plan.centers[c]] == c


def test_brain_storm_p1_p2_one_is_noop():
    """p1 = p2 = 1.0 => r > p never fires: assignments untouched, no
    events, centers are the per-cluster best-validation members."""
    for seed in range(10):
        rng = np.random.default_rng(seed)
        n, k = 14, 3
        a0 = rng.integers(0, k, size=n)
        val = rng.uniform(size=n).astype(np.float32)
        plan = brain_storm(rng, a0.copy(), val, k, 1.0, 1.0)
        np.testing.assert_array_equal(plan.assignments, a0)
        assert plan.events == []
        for c in range(k):
            members = np.where(a0 == c)[0]
            if len(members):
                assert plan.centers[c] == members[np.argmax(val[members])]


# ------------------------------------------------------------- aggregation

def _tree(x):
    return {"w": jnp.asarray(x, jnp.float32), "b": jnp.asarray([x[0]], jnp.float32)}


def test_fedavg_weighted_mean():
    t1, t2 = _tree([1.0, 2.0]), _tree([3.0, 6.0])
    out = fedavg([t1, t2], [1.0, 3.0])
    np.testing.assert_allclose(np.asarray(out["w"]), [2.5, 5.0])


def test_cluster_fedavg_matches_manual():
    stacked = {"w": jnp.asarray([[1.0], [3.0], [10.0], [20.0]])}
    assignments = jnp.asarray([0, 0, 1, 1])
    weights = jnp.asarray([1.0, 1.0, 1.0, 3.0])
    out = cluster_fedavg(stacked, assignments, weights, k=2)
    np.testing.assert_allclose(np.asarray(out["w"][:, 0]),
                               [2.0, 2.0, 17.5, 17.5])


def test_cluster_fedavg_identity_for_singleton_clusters():
    stacked = {"w": jax.random.normal(KEY, (3, 4))}
    out = cluster_fedavg(stacked, jnp.asarray([0, 1, 2]),
                         jnp.asarray([5.0, 1.0, 2.0]), k=3)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(stacked["w"]), rtol=1e-6)


def test_cluster_psum_fedavg_single_client_mesh():
    """Fleet-regime path on a 1-device 'pod' mesh: aggregation of a
    single client is the identity."""
    from jax.sharding import PartitionSpec as P
    from repro.core.aggregation import cluster_psum_fedavg
    mesh = jax.make_mesh((1,), ("pod",))
    params = {"w": jnp.asarray([[1.0, 2.0]])}

    def body(p, w, c):
        inner = jax.tree.map(lambda x: x[0], p)
        out = cluster_psum_fedavg(inner, w[0], c[0], 3, "pod")
        return jax.tree.map(lambda x: x[None], out)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P("pod"), P("pod"), P("pod")),
                   out_specs=P("pod"))
    out = fn(params, jnp.asarray([2.0]), jnp.asarray([1], jnp.int32))
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(params["w"]))


@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="needs >=4 devices (run via ./test.sh)")
def test_cluster_fedavg_matches_psum_fedavg_shard_map():
    """Sim-regime segment-sum Eq.2 == fleet-regime masked-psum Eq.2 on a
    real multi-device 'pod' mesh."""
    from jax.sharding import PartitionSpec as P
    from repro.core.aggregation import cluster_psum_fedavg
    n, k = 4, 2
    mesh = jax.make_mesh((n,), ("pod",))
    stacked = {"w": jax.random.normal(KEY, (n, 3, 2)),
               "b": jax.random.normal(jax.random.PRNGKey(7), (n, 5))}
    assignments = jnp.asarray([0, 1, 0, 1], jnp.int32)
    weights = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    expect = cluster_fedavg(stacked, assignments, weights, k=k)

    def body(p, w, c):
        inner = jax.tree.map(lambda x: x[0], p)
        out = cluster_psum_fedavg(inner, w[0], c[0], k, "pod")
        return jax.tree.map(lambda x: x[None], out)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P("pod"), P("pod"), P("pod")),
                   out_specs=P("pod"))
    got = fn(stacked, weights, assignments)
    for key in ("w", "b"):
        np.testing.assert_allclose(np.asarray(got[key]),
                                   np.asarray(expect[key]),
                                   rtol=1e-5, atol=1e-6)
