"""BSO-SL core unit tests: distribution stats, k-means, brain storm,
cluster aggregation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import cluster_fedavg, fedavg
from repro.core.bso import brain_storm
from repro.core.diststats import (full_params_bytes, param_distribution,
                                  upload_bytes)
from repro.core.kmeans import assign, kmeans

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------- diststats

def test_param_distribution_deterministic_order():
    p = {"b": jnp.ones((3, 3)), "a": jnp.zeros((5,)),
         "c": {"x": jnp.full((2,), 2.0)}}
    f1 = param_distribution(p)
    f2 = param_distribution({"c": {"x": jnp.full((2,), 2.0)},
                             "a": jnp.zeros((5,)), "b": jnp.ones((3, 3))})
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    # a: mean 0 var 0; b: mean 1 var 0; c/x: mean 2 var 0
    np.testing.assert_allclose(np.asarray(f1),
                               [0, 0, 1, 0, 2, 0], atol=1e-7)


def test_upload_bytes_is_tiny_vs_full_params():
    p = {"w1": jnp.zeros((256, 256)), "w2": jnp.zeros((1024,))}
    assert upload_bytes(p) == 2 * 2 * 4
    assert full_params_bytes(p) == (256 * 256 + 1024) * 4
    assert upload_bytes(p) < full_params_bytes(p) / 1000


# ------------------------------------------------------------------ kmeans

def test_kmeans_separates_obvious_clusters():
    a = jax.random.normal(KEY, (10, 4)) * 0.1
    b = jax.random.normal(jax.random.PRNGKey(1), (10, 4)) * 0.1 + 10.0
    X = jnp.concatenate([a, b])
    _, assignments = kmeans(KEY, X, 2, iters=10)
    a_ids = set(np.asarray(assignments[:10]).tolist())
    b_ids = set(np.asarray(assignments[10:]).tolist())
    assert len(a_ids) == 1 and len(b_ids) == 1 and a_ids != b_ids


def test_kmeans_assign_is_nearest():
    X = jax.random.normal(KEY, (20, 3))
    C = jax.random.normal(jax.random.PRNGKey(1), (4, 3))
    a = assign(X, C)
    d = jnp.sum((X[:, None, :] - C[None]) ** 2, axis=-1)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(jnp.argmin(d, 1)))


def test_kmeans_no_empty_clusters_with_enough_points():
    X = jax.random.normal(KEY, (14, 6))
    _, a = kmeans(KEY, X, 3, iters=30)
    assert len(set(np.asarray(a).tolist())) == 3


# -------------------------------------------------------------- brain storm

def _plan(seed, p1, p2, val=None, assignments=None, k=3, n=14):
    rng = np.random.default_rng(seed)
    val = np.linspace(0, 1, n) if val is None else val
    assignments = np.arange(n) % k if assignments is None else assignments
    return brain_storm(rng, assignments, val, k, p1, p2)


def test_centers_are_best_val_members_when_no_disruption():
    # p1 = p2 = 1.0 => r > p never fires: pure center selection
    plan = _plan(0, 1.0, 1.0)
    for c in range(3):
        members = np.where(plan.assignments == c)[0]
        best = members[np.argmax(np.linspace(0, 1, 14)[members])]
        assert plan.centers[c] == best
    assert plan.events == []


def test_replacement_fires_with_p1_zero():
    plan = _plan(3, 0.0, 1.0)
    # every cluster's center replaced by a random member (still a member)
    for c in range(3):
        members = set(np.where(plan.assignments == c)[0].tolist())
        assert int(plan.centers[c]) in members


def test_swap_exchanges_cluster_membership():
    plan = _plan(5, 1.0, 0.0)   # swaps fire every cluster
    assert any("swap" in e for e in plan.events)
    # assignments remain a permutation-consistent partition of clients
    assert sorted(np.unique(plan.assignments).tolist()) == [0, 1, 2] or \
        len(np.unique(plan.assignments)) <= 3


def test_paper_probabilities():
    """p1=0.9/p2=0.8 with r>p trigger => ~10% / ~20% event rates."""
    n_rep, n_swap = 0, 0
    trials = 2000
    for s in range(trials):
        plan = _plan(s, 0.9, 0.8)
        n_rep += sum("replace" in e for e in plan.events)
        n_swap += sum("swap" in e for e in plan.events)
    rep_rate = n_rep / (trials * 3)
    swap_rate = n_swap / (trials * 3)
    assert 0.05 < rep_rate < 0.15, rep_rate        # ~0.1 (minus no-op draws)
    assert 0.10 < swap_rate < 0.30, swap_rate      # ~0.2
    # swaps are pairwise: both clusters record one event jointly => the
    # per-cluster *initiation* rate is what we bound


# ------------------------------------------------------------- aggregation

def _tree(x):
    return {"w": jnp.asarray(x, jnp.float32), "b": jnp.asarray([x[0]], jnp.float32)}


def test_fedavg_weighted_mean():
    t1, t2 = _tree([1.0, 2.0]), _tree([3.0, 6.0])
    out = fedavg([t1, t2], [1.0, 3.0])
    np.testing.assert_allclose(np.asarray(out["w"]), [2.5, 5.0])


def test_cluster_fedavg_matches_manual():
    stacked = {"w": jnp.asarray([[1.0], [3.0], [10.0], [20.0]])}
    assignments = jnp.asarray([0, 0, 1, 1])
    weights = jnp.asarray([1.0, 1.0, 1.0, 3.0])
    out = cluster_fedavg(stacked, assignments, weights, k=2)
    np.testing.assert_allclose(np.asarray(out["w"][:, 0]),
                               [2.0, 2.0, 17.5, 17.5])


def test_cluster_fedavg_identity_for_singleton_clusters():
    stacked = {"w": jax.random.normal(KEY, (3, 4))}
    out = cluster_fedavg(stacked, jnp.asarray([0, 1, 2]),
                         jnp.asarray([5.0, 1.0, 2.0]), k=3)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(stacked["w"]), rtol=1e-6)


def test_cluster_psum_fedavg_single_client_mesh():
    """Fleet-regime path on a 1-device 'pod' mesh: aggregation of a
    single client is the identity."""
    from jax.sharding import PartitionSpec as P
    from repro.core.aggregation import cluster_psum_fedavg
    mesh = jax.make_mesh((1,), ("pod",))
    params = {"w": jnp.asarray([[1.0, 2.0]])}

    def body(p, w, c):
        inner = jax.tree.map(lambda x: x[0], p)
        out = cluster_psum_fedavg(inner, w[0], c[0], 3, "pod")
        return jax.tree.map(lambda x: x[None], out)

    fn = jax.shard_map(body, mesh=mesh,
                       in_specs=(P("pod"), P("pod"), P("pod")),
                       out_specs=P("pod"))
    out = fn(params, jnp.asarray([2.0]), jnp.asarray([1], jnp.int32))
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(params["w"]))
