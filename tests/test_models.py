"""Model-zoo behaviour: forward/loss sanity and the strongest invariant
we have — token-by-token decode must reproduce the teacher-forced
forward pass for every family (validates KV caches, RoPE offsets,
ring-buffer masks, SSD chunked-vs-recurrent math, MoE dispatch)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model

KEY = jax.random.PRNGKey(0)

DECODER_ARCHS = ["granite-3-2b", "deepseek-7b", "kimi-k2-1t-a32b",
                 "llama4-maverick-400b-a17b", "mamba2-370m", "zamba2-1.2b",
                 "internvl2-26b", "command-r-35b", "deepseek-67b"]


def _batch_for(cfg, B=2, S=32):
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["vision_embed"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_vision_tokens, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", DECODER_ARCHS + ["whisper-base"])
def test_forward_finite(arch):
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params = model.init(KEY)
    if cfg.family == "encdec":
        batch = {"audio_embed": jax.random.normal(KEY, (2, 64, cfg.d_model)) * 0.02,
                 "tokens": jnp.ones((2, 16), jnp.int32),
                 "labels": jnp.ones((2, 16), jnp.int32)}
    else:
        batch = _batch_for(cfg)
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss))
    assert 0.0 <= float(metrics["acc"]) <= 1.0


@pytest.mark.parametrize("arch", ["granite-3-2b", "mamba2-370m",
                                  "zamba2-1.2b", "kimi-k2-1t-a32b"])
def test_decode_matches_forward(arch):
    cfg = get_config(arch).smoke()
    if cfg.ssm_state:
        cfg = dataclasses.replace(cfg, ssm_chunk=8)
    if cfg.n_experts:
        # decode-vs-forward equivalence only holds when no token is
        # capacity-dropped (drops depend on batch composition); give the
        # router headroom so routing is drop-free in both passes.
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(KEY)
    S = 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, S), 0, cfg.vocab_size)
    full, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(2, S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, toks[:, t:t + 1], cache,
                                      jnp.asarray(t, jnp.int32))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-4)


def test_decode_matches_forward_scanned():
    cfg = dataclasses.replace(get_config("granite-3-2b").smoke(),
                              scan_layers=True, n_layers=4)
    model = build_model(cfg)
    params = model.init(KEY)
    S = 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, S), 0, cfg.vocab_size)
    full, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(2, S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, toks[:, t:t + 1], cache,
                                      jnp.asarray(t, jnp.int32))
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)), np.asarray(full),
                               rtol=2e-3, atol=2e-4)


def test_sliding_window_matches_full_when_window_covers():
    """window >= seq ==> identical logits; small window ==> different."""
    base = get_config("granite-3-2b").smoke()
    model = build_model(base)
    params = model.init(KEY)
    toks = jax.random.randint(KEY, (2, 24), 0, base.vocab_size)

    wide = dataclasses.replace(base, sliding_window=64)
    narrow = dataclasses.replace(base, sliding_window=4)
    full, _ = build_model(base).forward(params, {"tokens": toks})
    w, _ = build_model(wide).forward(params, {"tokens": toks})
    n, _ = build_model(narrow).forward(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(w), np.asarray(full), rtol=1e-5, atol=1e-5)
    assert float(jnp.max(jnp.abs(n - full))) > 1e-3


def test_chunked_attention_matches_unchunked():
    """The q-chunked prefill path (used above CHUNK_THRESHOLD) must equal
    the plain path."""
    from repro.models import attention as A
    cfg = get_config("granite-3-2b").smoke()
    model = build_model(cfg)
    params = model.init(KEY)
    S = 64
    toks = jax.random.randint(KEY, (1, S), 0, cfg.vocab_size)
    ref, _ = model.forward(params, {"tokens": toks})
    old_thr, old_cq = A.CHUNK_THRESHOLD, A.CHUNK_Q
    try:
        A.CHUNK_THRESHOLD, A.CHUNK_Q = 16, 16
        chunked, _ = model.forward(params, {"tokens": toks})
    finally:
        A.CHUNK_THRESHOLD, A.CHUNK_Q = old_thr, old_cq
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= 1 and near-uniform routing, most tokens
    survive dispatch: output must differ from a pure shared-expert path
    and gradients must exist for expert weights."""
    cfg = get_config("kimi-k2-1t-a32b").smoke()
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch_for(cfg, B=2, S=16)

    def loss_fn(p):
        return model.loss(p, batch)[0]

    grads = jax.grad(loss_fn)(params)
    wi_grads = jax.tree_util.tree_leaves(
        {k: v for k, v in grads.items() if k == "blocks"})
    total = sum(float(jnp.sum(jnp.abs(g))) for g in wi_grads)
    assert total > 0.0


def test_train_step_reduces_loss():
    from repro.configs.base import OptimizerConfig
    from repro.optim.optimizers import make_optimizer
    from repro.train.steps import make_train_step
    cfg = get_config("granite-3-2b").smoke()
    model = build_model(cfg)
    params = model.init(KEY)
    opt = make_optimizer(OptimizerConfig(name="adamw", lr=5e-3))
    state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    batch = _batch_for(cfg, B=4, S=32)
    losses = []
    for _ in range(8):
        params, state, m = step(params, state, batch, jnp.asarray(5e-3))
        losses.append(float(m["ce"]))
    assert losses[-1] < losses[0]


def test_microbatched_step_matches_full_batch_grads():
    """Gradient accumulation must equal the full-batch gradient."""
    from repro.configs.base import OptimizerConfig
    from repro.optim.optimizers import make_optimizer
    from repro.train.steps import make_train_step
    cfg = get_config("granite-3-2b").smoke()
    model = build_model(cfg)
    params = model.init(KEY)
    opt = make_optimizer(OptimizerConfig(name="sgd", lr=1e-2, grad_clip=0))
    state = opt.init(params)
    batch = _batch_for(cfg, B=4, S=16)
    full = make_train_step(model, opt)
    micro = make_train_step(model, opt, microbatches=2)
    p1, _, _ = full(params, state, batch, jnp.asarray(1e-2))
    p2, _, _ = micro(params, state, batch, jnp.asarray(1e-2))
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)
