"""Fleet-driver tests: the end-to-end multi-round BSO-SL loop (PR 5).

Covers the acceptance properties of ``repro.launch.fleet_driver``:

* the driver runs full rounds with exactly ONE compiled fleet-round
  executable, threading each round's host coordinator decision into
  the next round's clusters (the stats -> k-means/BSA -> clusters loop
  the ROADMAP fleet item asked for),
* the host coordinator is deterministic given the uploaded stats, and
  the driver's per-round assignments are exactly host ``kmeans`` +
  numpy ``brain_storm`` on the stats it pulled,
* donated-buffer reuse across rounds never retraces (jit cache-size),
* sim parity: at unit scale the driver's val-acc trajectory matches
  the sim engine's ``run_rounds`` statistically (same protocol; the
  RNG streams differ — host batch sampling and the numpy brain storm
  vs the engine's in-program draws — the same documented caveat as the
  numpy-oracle parity in ``tests/test_engine.py``).

Runs on whatever backend pytest sees: under ``./test.sh`` the 8-device
stand-in gives one clinic per device; under plain ``pytest`` the same
driver code runs on the trivial single-device pod mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import OptimizerConfig
from repro.core.bso import brain_storm
from repro.core.engine import (EngineConfig, jit_run_rounds, make_swarm_data,
                               make_swarm_state)
from repro.core.kmeans import kmeans
from repro.data.dr import TABLE_I, make_dr_swarm_data
from repro.launch.fleet_driver import (host_coordinator, make_unit_fleet,
                                       run_fleet, _sample_round_batch)
from repro.launch.mesh import make_fleet_mesh
from repro.launch.swarm_fleet import fleet_setup
from repro.models import build_model
from repro.optim.optimizers import make_optimizer
from repro.sharding import use_sharding

N_CLIENTS = 8
SMALL_TABLE = np.maximum(TABLE_I // 16,
                         (TABLE_I > 0).astype(np.int64) * 2)[:, :N_CLIENTS]


@pytest.fixture(scope="module")
def unit_clients():
    return make_dr_swarm_data(image_size=16, seed=0, table=SMALL_TABLE)


@pytest.fixture(scope="module")
def unit_model():
    return build_model(get_config("squeezenet-dr"))


def _opt():
    return make_optimizer(OptimizerConfig(name="adam", lr=2e-3))


def test_fleet_driver_smoke(unit_model, unit_clients):
    """Tier-1 stage-4 smoke: 2 driver rounds, ONE compiled round step,
    well-formed protocol artifacts, and the loop actually closed (round
    1 aggregates round 0's coordinator decision)."""
    mesh = make_fleet_mesh(len(unit_clients))
    res = run_fleet(unit_model, _opt(), mesh, unit_clients, rounds=2,
                    local_steps=2, batch_size=8, seed=0)
    assert res.n_compiles == 1
    assert len(res.history) == 2
    for log in res.history:
        assert 0.0 <= log.mean_val_acc <= 1.0
        assert np.isfinite(log.train_loss)
        assert log.stats.shape[0] == len(unit_clients)
        assert log.stats.ndim == 2 and log.stats.shape[1] % 2 == 0
        assert set(log.assignments.tolist()) <= {0, 1, 2}
    # round 0 is seeded with the identity plan; round 1 applies the
    # clusters decided from round 0's stat upload
    np.testing.assert_array_equal(res.history[0].applied_clusters,
                                  np.arange(len(unit_clients)))
    np.testing.assert_array_equal(res.history[1].applied_clusters,
                                  res.history[0].assignments)


def test_fleet_driver_three_rounds_coordinator_loop(unit_model,
                                                    unit_clients):
    """Acceptance: >= 3 full rounds, one executable, and per round the
    recorded cluster decision is EXACTLY host k-means + numpy
    brain_storm on the stats/val scores the driver pulled — replayed
    both through ``host_coordinator`` (determinism) and through the
    underlying pieces directly (the contract is the paper's
    neighbour-assignment server, not a private code path)."""
    seed, k, p1, p2, iters = 3, 3, 0.9, 0.8, 20
    mesh = make_fleet_mesh(len(unit_clients))
    res = run_fleet(unit_model, _opt(), mesh, unit_clients, rounds=3,
                    local_steps=2, batch_size=8, seed=seed, n_clusters=k,
                    p1=p1, p2=p2, kmeans_iters=iters)
    assert res.n_compiles == 1 and len(res.history) == 3
    for r, log in enumerate(res.history):
        # deterministic replay through the coordinator entry point
        a1, c1, _ = host_coordinator(log.stats, log.val_acc, k=k, p1=p1,
                                     p2=p2, kmeans_iters=iters, seed=seed,
                                     round_idx=r)
        np.testing.assert_array_equal(a1, log.assignments)
        np.testing.assert_array_equal(c1, log.centers)
        # independent replay through kmeans + brain_storm themselves
        key = jax.random.fold_in(jax.random.PRNGKey(seed), r)
        _, a0 = kmeans(key, jnp.asarray(log.stats, jnp.float32), k=k,
                       iters=iters)
        plan = brain_storm(np.random.default_rng([seed, r]),
                           np.asarray(a0), log.val_acc, k, p1, p2)
        np.testing.assert_array_equal(plan.assignments, log.assignments)
        np.testing.assert_array_equal(plan.centers, log.centers)
        # and the loop closure: decision r aggregates in round r+1
        if r + 1 < len(res.history):
            np.testing.assert_array_equal(res.history[r + 1].applied_clusters,
                                          log.assignments)


def test_fleet_round_donated_reuse_does_not_retrace(unit_model,
                                                    unit_clients):
    """Round-over-round reuse of the donated params/opt buffers with
    fresh host batches and fresh cluster plans must hit the jit cache:
    ONE traced/compiled program for any number of rounds."""
    N = len(unit_clients)
    mesh = make_fleet_mesh(N)
    opt = _opt()
    program = fleet_setup(unit_model, opt, mesh, k=N, n_local_steps=2,
                          with_eval=True, donate=True, spmd="shard_map")
    psh, osh, bsh, vsh, lsh, csh, wsh = program.in_shardings
    with mesh, use_sharding(mesh, program.rules):
        keys = jax.random.split(jax.random.PRNGKey(0), N)
        sparams = jax.device_put(jax.vmap(unit_model.init)(keys), psh)
        sopt = jax.device_put(jax.vmap(opt.init)(sparams), osh)
        val = jax.device_put(
            make_swarm_data(unit_model.cfg, unit_clients).val, vsh)
        weights = jax.device_put(
            jnp.asarray([c["n_train"] for c in unit_clients], jnp.float32),
            wsh)
        lr = jax.device_put(jnp.float32(2e-3), lsh)
        rng = np.random.default_rng(0)
        for r in range(3):
            batch = jax.device_put(
                _sample_round_batch(unit_model.cfg, unit_clients, 16,
                                    seed=0, round_idx=r), bsh)
            clusters = jax.device_put(
                jnp.asarray(rng.integers(0, 3, size=N), jnp.int32), csh)
            sparams, sopt, out = program.jit_fn(sparams, sopt, batch, val,
                                                lr, clusters, weights)
            assert np.isfinite(float(out.train_loss))
            assert program.jit_fn._cache_size() == 1, \
                f"fleet round retraced at round {r}"


def test_fleet_driver_bucketed_eval_parity(unit_model, unit_clients):
    """Bucketed ragged eval on the driver: the round program carries no
    rectangular val stack (with_loss surface), each size bucket gets
    ONE fixed-shape compiled eval program, and every round's val
    accuracies / coordinator decisions / losses match the in-program
    rectangular eval exactly — at a compile budget of 1 + n_buckets
    with zero per-round retraces."""
    mesh = make_fleet_mesh(len(unit_clients))
    kw = dict(rounds=2, local_steps=2, batch_size=8, seed=0)
    res_r = run_fleet(unit_model, _opt(), mesh, unit_clients, **kw)
    res_b = run_fleet(unit_model, _opt(), mesh, unit_clients,
                      eval_buckets=3, **kw)
    n_buckets = res_b.meta["eval_buckets"]
    assert 2 <= n_buckets <= 3
    assert res_b.n_compiles == 1 + n_buckets
    assert res_r.meta["eval_buckets"] == 0 and res_r.n_compiles == 1
    for lr_, lb in zip(res_r.history, res_b.history):
        np.testing.assert_array_equal(lr_.val_acc, lb.val_acc)
        np.testing.assert_array_equal(lr_.assignments, lb.assignments)
        np.testing.assert_array_equal(lr_.stats, lb.stats)
        assert lr_.train_loss == lb.train_loss


@pytest.mark.parametrize("seed", [
    0,
    pytest.param(7, marks=pytest.mark.slow),
    pytest.param(23, marks=pytest.mark.slow),
])
def test_fleet_driver_matches_sim_engine_statistically(unit_model,
                                                       unit_clients, seed):
    """Sim parity: the driver executes the engine's protocol sequence
    (train -> eval -> stats -> coordinator -> Eq. 2 per round, with the
    driver's final Eq. 2 pending), so at unit scale the two val-acc
    trajectories must agree statistically — different RNG streams, same
    documented caveat as the engine's numpy-oracle parity. Tier-1 runs
    the pinned seed; the slow replicas (nightly ``--runslow``) guard
    against the one-seed pass being luck."""
    rounds, local_steps = 4, 10
    mesh = make_fleet_mesh(len(unit_clients))
    res = run_fleet(unit_model, _opt(), mesh, unit_clients, rounds=rounds,
                    local_steps=local_steps, batch_size=8, seed=seed)
    fleet = res.mean_val_accs

    opt = _opt()
    cfg = EngineConfig(model=unit_model, opt=opt, local_steps=local_steps,
                       batch_size=8, lr=2e-3, aggregation="bso",
                       n_clusters=3, p1=0.9, p2=0.8, kmeans_iters=20)
    data = make_swarm_data(unit_model.cfg, unit_clients)
    state = make_swarm_state(unit_model, opt, unit_clients,
                             jax.random.PRNGKey(seed))
    _, ms = jit_run_rounds(state, data, cfg, rounds)
    sim = np.asarray(ms.mean_val_acc).tolist()

    # both learn past the 5-class random floor by the end...
    assert np.mean(fleet[-2:]) > 0.25, (fleet, sim)
    assert np.mean(sim[-2:]) > 0.25, (fleet, sim)
    # ...and the settled halves of the trajectories agree
    assert abs(np.mean(fleet[-2:]) - np.mean(sim[-2:])) < 0.2, (fleet, sim)


def test_unit_fleet_builder_shapes():
    """make_unit_fleet clips the Table-I clinic axis and builds a pod
    mesh whose client axis divides the clinic count."""
    model, opt, mesh, clients = make_unit_fleet(n_clients=4, image_size=8,
                                                data_scale=32)
    assert len(clients) == 4
    assert 4 % mesh.shape["pod"] == 0
    assert tuple(mesh.axis_names) == ("pod", "data", "model")
    assert model.cfg.arch_id == "squeezenet-dr"
