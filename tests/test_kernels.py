"""Pallas kernel validation (deliverable c): shape/dtype sweeps,
interpret mode vs the pure-jnp oracles in repro.kernels.ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _qkv(B, H, KV, Sq, Sk, D, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, Sq, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, KV, Sk, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, KV, Sk, D), jnp.float32).astype(dtype)
    return q, k, v


FLASH_CASES = [
    # B, H, KV, S, D, causal, window, bq, bk
    (1, 4, 4, 128, 64, True, 0, 64, 64),
    (2, 8, 2, 256, 64, True, 0, 128, 128),
    (1, 8, 1, 256, 128, True, 0, 64, 128),
    (2, 4, 4, 128, 64, False, 0, 64, 64),
    (1, 4, 2, 256, 64, True, 64, 64, 64),
    (1, 2, 2, 512, 64, True, 128, 128, 256),
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(case, dtype):
    B, H, KV, S, D, causal, win, bq, bk = case
    q, k, v = _qkv(B, H, KV, S, S, D, dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=win,
                              block_q=bq, block_k=bk)
    expect = ref.ref_attention(q, k, v, causal=causal, window=win)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


DECODE_CASES = [
    # B, H, KV, S, D, pos, window, bk
    (2, 4, 2, 512, 64, 100, 0, 128),
    (1, 8, 2, 1024, 128, 1023, 0, 256),
    (2, 4, 4, 512, 64, 300, 128, 128),
    (1, 4, 1, 256, 64, 0, 0, 64),
    # cache length NOT a multiple of block_k (serve buckets are free to
    # pick any ceiling): the kernel zero-pads the tile axis
    (2, 4, 2, 200, 64, 150, 0, 64),
    (1, 4, 2, 80, 64, 79, 32, 64),
]


@pytest.mark.parametrize("pos_list,S,win,bk", [
    ([3, 100, 511], 512, 0, 128),       # per-row positions (serve slots)
    ([0, 37], 96, 0, 64),               # S % block_k != 0
    ([10, 250], 256, 64, 64),           # sliding window + vector pos
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_vector_pos(pos_list, S, win, bk, dtype):
    """(B,) per-row positions — each cache slot decoding at its own
    sequence point, the continuous-batching engine's hot path."""
    B, H, KV, D = len(pos_list), 4, 2, 64
    q, k, v = _qkv(B, H, KV, 1, S, D, dtype)
    pos = jnp.asarray(pos_list, jnp.int32)
    out = ops.flash_decode(q, k, v, pos, window=win, block_k=bk)
    expect = ref.ref_decode_attention(q, k, v, pos, window=win)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)
    # vector pos must agree row-for-row with scalar-pos calls
    for b, p in enumerate(pos_list):
        one = ops.flash_decode(q[b:b + 1], k[b:b + 1], v[b:b + 1],
                               jnp.asarray(p, jnp.int32),
                               window=win, block_k=bk)
        np.testing.assert_allclose(np.asarray(out[b:b + 1], np.float32),
                                   np.asarray(one, np.float32),
                                   rtol=tol, atol=tol)


@pytest.mark.parametrize("case", DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(case, dtype):
    B, H, KV, S, D, pos, win, bk = case
    q, k, v = _qkv(B, H, KV, 1, S, D, dtype)
    out = ops.flash_decode(q, k, v, jnp.asarray(pos, jnp.int32),
                           window=win, block_k=bk)
    expect = ref.ref_decode_attention(q, k, v, pos, window=win)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", [(7,), (1000,), (333, 77), (8, 128),
                                   (3, 5, 17), (4096,)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_param_stats_sweep(shape, dtype):
    x = (jax.random.normal(KEY, shape) * 2.5 - 0.7).astype(dtype)
    m, v = ops.param_stats(x)
    rm, rv = ref.ref_param_stats(x)
    np.testing.assert_allclose(float(m), float(rm), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(float(v), float(rv), rtol=1e-2, atol=1e-2)


@pytest.mark.parametrize("mean,n", [(1e4, 4096), (1e3, 300_001), (-5e3, 70_000)])
def test_param_stats_large_mean_no_cancellation(mean, n):
    """Regression: the one-pass ss/n - mean^2 form lost ~half the fp32
    mantissa when mean^2 >> var (var ~0.25 vs mean^2 ~1e8 came back as
    exactly 0). The shifted accumulation must track the jnp.var oracle."""
    x = jax.random.normal(KEY, (n,)) * 0.5 + mean
    m, v = ops.param_stats(x)
    rm, rv = ref.ref_param_stats(x)
    np.testing.assert_allclose(float(m), float(rm), rtol=1e-5)
    np.testing.assert_allclose(float(v), float(rv), rtol=1e-2)
    assert float(v) > 0.1        # the unshifted kernel clamped this to 0


@pytest.mark.parametrize("shape", [(3, 1000), (8, 33, 7), (2, 70000),
                                   (5, 7), (1, 4096), (14, 8, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_param_stats_batched_sweep(shape, dtype):
    """Client-batched kernel vs the vmapped jnp oracle."""
    x = (jax.random.normal(KEY, shape) * 2.0 + 1.3).astype(dtype)
    m, v = ops.param_stats_batched(x)
    rm, rv = ref.ref_param_stats_batched(x)
    assert m.shape == v.shape == (shape[0],)
    np.testing.assert_allclose(np.asarray(m), np.asarray(rm),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv),
                               rtol=1e-2, atol=1e-2)


@pytest.mark.parametrize("N,F,K", [(14, 6, 3), (37, 10, 3), (130, 260, 5),
                                   (3, 4, 3)])
def test_kmeans_assign_sweep(N, F, K):
    X = jax.random.normal(KEY, (N, F))
    C = jax.random.normal(jax.random.PRNGKey(1), (K, F))
    out = ops.kmeans_assign(X, C)
    expect = ref.ref_kmeans_assign(X, C)
    assert np.array_equal(np.asarray(out), np.asarray(expect))


def test_flash_attention_matches_model_attention():
    """The kernel and the model's jnp path implement the same math."""
    from repro.configs import get_config
    from repro.models import attention as A
    cfg = get_config("granite-3-2b").smoke()
    model_p = A.init_attention(KEY, cfg)
    B, S = 2, 64
    x = jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.1
    out_model = A.attend_full(model_p, x, cfg)

    q, k, v = A._project_qkv(model_p, x, x, cfg)
    pos = jnp.arange(S)[None, :]
    q = A.apply_rope(q, pos, cfg.rope_theta)
    k = A.apply_rope(k, pos, cfg.rope_theta)
    o = ops.flash_attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                            jnp.swapaxes(v, 1, 2), causal=True,
                            block_q=32, block_k=32)
    o = jnp.swapaxes(o, 1, 2).reshape(B, S, -1) @ model_p["wo"]
    np.testing.assert_allclose(np.asarray(o), np.asarray(out_model),
                               rtol=1e-4, atol=1e-4)
