"""Beyond-paper ablation: cluster count k and brain-storm probabilities
as ONE vmapped grid program (PR 4), plus the fused-round benchmark
(PR 2).

The paper fixes k=3, p1=0.9, p2=0.8 without ablation; this benchmark
sweeps them so the mechanism's contribution is measurable:
  * k=1 reduces BSO-SL to FedAvg (sanity anchor),
  * p1=p2=1.0 disables the brain-storm disruption entirely,
  * p1=p2=0.0 maximises disruption.

Since the grid engine, the whole ablation is ``run_grid_table`` — one
compiled executable for all points, sharing one device-resident
SwarmData — instead of |grid| serial ``SwarmTrainer.fit`` loops. The
serial loop survives as the *parity oracle*: each grid row must
reproduce the stateful ``SwarmTrainer`` slice (static n_clusters/p1/p2,
aligned PRNG chain) bitwise. ``grid_bench`` times the collapse on the
acceptance grid (k x p1) and writes the ``BENCH_grid.json`` artifact.

``fused_round_bench`` measures the engine redesign: the PR1-style
host-driven round (per-step numpy batch sampling + separate device
programs per coordinator phase + numpy brain storm) against the PR2
single-jit'd-program ``swarm_round`` and the scanned multi-round
``run_rounds``, writing a ``BENCH_round.json`` artifact.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.configs import get_config
from repro.configs.base import OptimizerConfig, SwarmConfig
from repro.core.aggregation import cluster_fedavg
from repro.core.baselines import run_grid_point, run_grid_table, sweep_keys
from repro.core.bso import brain_storm
from repro.core.diststats import (swarm_distribution_matrix,
                                  swarm_distribution_matrix_loop)
from repro.core.engine import (EngineConfig, grid_axes, jit_run_rounds,
                               jit_swarm_round, make_batch, make_client_eval,
                               make_swarm_data, make_swarm_state,
                               stack_eval_split)
from repro.core.kmeans import kmeans
from repro.core.swarm import SwarmTrainer, eval_client
from repro.data.dr import make_dr_swarm_data, scale_table
from repro.models import build_model
from repro.optim.optimizers import make_optimizer
from repro.train.steps import make_train_step
from repro.utils.tree import tree_index, tree_paths_and_leaves

#: beyond-paper ablation points (grid_point specs; name -> spec)
CASES = [
    ("k1_fedavg_like", dict(k=1)),
    ("k3_paper", dict(k=3)),
    ("k5", dict(k=5)),
    ("k3_no_brainstorm", dict(k=3, p1=1.0, p2=1.0)),
    ("k3_max_disruption", dict(k=3, p1=0.0, p2=0.0)),
]

#: the acceptance grid for BENCH_grid.json (k x p1, 6 points)
GRID_AXES = dict(k=(1, 2, 3), p1=(0.9, 1.0))


def run(data_scale: int = 2, rounds: int = 6, local_steps: int = 10,
        seed: int = 0, serial_oracle: bool = True):
    """The CASES ablation as ONE run_grid_table program; with
    ``serial_oracle`` each row is checked against the stateful
    ``SwarmTrainer`` loop it replaced (static knobs, PRNG chain aligned
    by fitting with ``split(row_key)[1]`` — make_swarm_state's round
    key), which keeps the old serial path honest AND covered."""
    clients = make_dr_swarm_data(image_size=20, seed=seed,
                                 table=scale_table(data_scale))
    model = build_model(get_config("squeezenet-dr"))
    opt = OptimizerConfig(name="adam", lr=2e-3)
    swarm = SwarmConfig(n_clients=14, rounds=rounds, local_steps=local_steps)
    specs = [spec for _, spec in CASES]

    t0 = time.time()
    results, _ = run_grid_table(model, clients, swarm, opt,
                                jax.random.PRNGKey(seed), specs=specs,
                                batch_size=8)
    us_grid = (time.time() - t0) * 1e6
    out = {}
    for (name, _), res in zip(CASES, results):
        out[name] = res["acc"]
        row(f"ablation/{name}", us_grid / len(CASES), f"acc={res['acc']:.4f}")
    row("ablation/grid_program", us_grid,
        f"programs=1;points={len(CASES)};rounds={rounds}")

    if serial_oracle:
        keys = sweep_keys(jax.random.PRNGKey(seed), specs)
        for (name, spec), key in zip(CASES, keys):
            t0 = time.time()
            tr = SwarmTrainer(model, clients,
                              SwarmConfig(n_clients=14, rounds=rounds,
                                          local_steps=local_steps,
                                          n_clusters=spec["k"],
                                          p1=spec.get("p1", 0.9),
                                          p2=spec.get("p2", 0.8)),
                              opt, key, batch_size=8, aggregation="bso")
            tr.fit(jax.random.split(key)[1])
            acc = tr.mean_accuracy("test")
            row(f"ablation/serial/{name}", (time.time() - t0) * 1e6,
                f"acc={acc:.4f};grid_acc={out[name]:.4f};"
                f"parity={abs(acc - out[name]):.2e}")
    return out


def grid_bench(data_scale: int = 4, rounds: int = 4, local_steps: int = 6,
               seed: int = 0, serial_reference: bool = True,
               out_json: str = "BENCH_grid.json"):
    """Tentpole measurement (PR 4): the k{1,2,3} x p1{0.9,1.0}
    hyper-parameter grid as ONE vmapped ``run_grid`` executable vs the
    serial per-point ``run_grid_point`` loop (one scanned program per
    point — itself already the post-PR-2 fast path; the pre-grid
    SwarmTrainer loop added a host dispatch per round on top). Writes
    ``BENCH_grid.json`` with accuracies, parity, and timings.
    """
    clients = make_dr_swarm_data(image_size=16, seed=seed,
                                 table=scale_table(data_scale))
    model = build_model(get_config("squeezenet-dr"))
    opt = OptimizerConfig(name="adam", lr=2e-3)
    swarm = SwarmConfig(n_clients=14, rounds=rounds, local_steps=local_steps)
    specs = grid_axes(**GRID_AXES)
    key = jax.random.PRNGKey(seed)

    t0 = time.time()
    results, _ = run_grid_table(model, clients, swarm, opt, key,
                                specs=specs, batch_size=8)
    us_grid = (time.time() - t0) * 1e6
    for res in results:
        tag = ";".join(f"{k}={v}" for k, v in res.items() if k != "acc")
        row(f"grid/{tag}", us_grid / len(specs), f"acc={res['acc']:.4f}")
    row("grid/one_program", us_grid,
        f"programs=1;points={len(specs)};rounds={rounds}")

    serial, us_serial, parity = [], {}, None
    if serial_reference:
        keys = sweep_keys(key, specs)
        for g, spec in enumerate(specs):
            t0 = time.time()
            acc, _ = run_grid_point(spec, model, clients, swarm, opt,
                                    keys[g], batch_size=8)
            tag = ";".join(f"{k}={v}" for k, v in spec.items())
            us_serial[tag] = (time.time() - t0) * 1e6
            serial.append(acc)
            row(f"grid/serial/{tag}", us_serial[tag],
                f"acc={acc:.4f};grid_acc={results[g]['acc']:.4f}")
        parity = max(abs(a - r["acc"]) for a, r in zip(serial, results))
        row("grid/serial_parity", 0.0, f"max_abs_acc_diff={parity:.2e}")

    artifact = {
        "axes": {k: list(v) for k, v in GRID_AXES.items()},
        "points": [{k: v for k, v in r.items() if k != "acc"}
                   for r in results],
        "n_clients": swarm.n_clients,
        "rounds": rounds,
        "local_steps": local_steps,
        "batch_size": 8,
        "data_scale": data_scale,
        "accs_grid": [r["acc"] for r in results],
        "accs_serial": serial or None,
        "us_grid_program": us_grid,
        "us_serial_per_point": us_serial or None,
        "us_serial_total": sum(us_serial.values()) if us_serial else None,
        # before the grid engine: one SwarmTrainer.fit per point with a
        # host dispatch per round; the serial reference here is already
        # the stronger one-scanned-program-per-point baseline
        "programs_before": len(specs) * rounds,
        "programs_serial_run_grid_point": len(specs),
        "programs_grid": 1,
        "parity_max_abs_acc_diff": parity,
        "note": "Wall-clocks are end-to-end (compile + run) on the CPU "
                "backend, where the one-program grid can come out "
                "SLOWER than the serial loop: the vmapped fit keeps "
                "its local phase as a rolled lax.scan and XLA-CPU "
                "executes while-body ops ~2x slower than unrolled "
                "(the same artifact BENCH_round.json documents), and "
                "row-stacked convs vectorise poorly on CPU. The "
                "transferable win is the program collapse (|grid| x "
                "rounds dispatches -> 1 vmapped executable sharing one "
                "device-resident SwarmData, static shapes from the row "
                "maxima k_max/local_steps_max) — on TPU, where "
                "per-dispatch overhead dominates, that is also the "
                "wall-clock win. Extends BENCH_sweep.json's "
                "method-axis collapse to the hyper-parameter axes the "
                "paper fixes without ablation. Per-point parity vs the "
                "serial oracle is bitwise on params "
                "(tests/test_grid.py); the acc diff here is rounding "
                "of the identical Eq.3 evaluation.",
    }
    if out_json:
        with open(out_json, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"[grid_bench] wrote {out_json}")
    return artifact


def coordinator_bench(n_clients: int = 64, seed: int = 0):
    """Tentpole measurement: the per-round coordinator phase
    (distribution stats + k-means + eval) as a handful of fused device
    programs vs the old per-client host loops.

      old: N·T tiny stat dispatches + sum_i ceil(n_i/64) eval dispatches
      new: 1 stats program + 1 jit'd Lloyd loop + 1 vmapped eval program
    """
    model = build_model(get_config("squeezenet-dr"))
    keys = jax.random.split(jax.random.PRNGKey(seed), n_clients)
    params = jax.vmap(model.init)(keys)
    n_tensors = len(tree_paths_and_leaves(params))

    # --- distribution stats: host loop (old) vs single fused pass (new)
    _, us_old = timed(lambda: swarm_distribution_matrix_loop(
        params, n_clients), warmup=1, iters=3)
    row(f"coordinator/stats_loop_N{n_clients}", us_old,
        f"programs={n_clients * n_tensors}")
    _, us_new = timed(lambda: swarm_distribution_matrix(
        params, n_clients), warmup=1, iters=3)
    row(f"coordinator/stats_batched_N{n_clients}", us_new,
        f"programs=1;speedup={us_old / us_new:.1f}x")

    # --- k-means: eager Lloyd (old) vs one jit'd program (new)
    feats = jax.block_until_ready(swarm_distribution_matrix(params, n_clients))
    kkey = jax.random.PRNGKey(seed + 1)
    _, us_old = timed(lambda: kmeans(kkey, feats, 3, 20), warmup=1, iters=3)
    row(f"coordinator/kmeans_eager_N{n_clients}", us_old, "programs=O(iters)")
    km = jax.jit(kmeans, static_argnames=("k", "iters", "use_pallas"))
    _, us_new = timed(lambda: km(kkey, feats, k=3, iters=20),
                      warmup=1, iters=3)
    row(f"coordinator/kmeans_jit_N{n_clients}", us_new,
        f"programs=1;speedup={us_old / us_new:.1f}x")

    # --- eval + full round on an N-client swarm (clinics cycled to N)
    clinics = make_dr_swarm_data(image_size=16, seed=seed,
                                 table=scale_table(8))
    clients = [clinics[i % len(clinics)] for i in range(n_clients)]
    swarm = SwarmConfig(n_clients=n_clients, rounds=1, local_steps=1)
    tr = SwarmTrainer(model, clients, swarm,
                      OptimizerConfig(name="adam", lr=2e-3),
                      jax.random.PRNGKey(seed), batch_size=8,
                      aggregation="bso")

    def eval_loop():
        return [eval_client(tr._eval, tr.cfg, tree_index(tr.params, i),
                            *tr.data[i]["val"]) for i in range(n_clients)]

    n_batches = sum(-(-len(c["val"][1]) // 64) for c in tr.data)
    _, us_old = timed(eval_loop, warmup=1, iters=3)
    row(f"coordinator/eval_loop_N{n_clients}", us_old,
        f"programs={n_batches}")
    _, us_new = timed(lambda: tr.client_scores("val"), warmup=1, iters=3)
    row(f"coordinator/eval_vmapped_N{n_clients}", us_new,
        f"programs=1;speedup={us_old / us_new:.1f}x")

    key = jax.random.PRNGKey(seed + 2)
    _, us_round = timed(lambda: tr.round(0, key), warmup=1, iters=3)
    row(f"coordinator/full_bso_round_N{n_clients}", us_round,
        "stats+kmeans+eval+agg batched")
    return None


def make_host_loop_round(model, opt, clients, *, local_steps: int,
                         batch_size: int, lr: float, k: int = 3,
                         p1: float = 0.9, p2: float = 0.8,
                         kmeans_iters: int = 20):
    """The PR1-era host-driven BSO round, kept as the single reference
    implementation (used by this benchmark's baseline AND the engine's
    trajectory-parity test): a per-step numpy sampling loop feeding a
    vmapped train step, then the coordinator as separate device
    programs + the numpy brain storm.

    Returns ``round_fn(params, opt_state, key, np_rng) ->
    (params, opt_state, mean_val_acc)``.
    """
    n_clients = len(clients)
    vstep = jax.jit(jax.vmap(make_train_step(model, opt),
                             in_axes=(0, 0, 0, None)))
    veval = jax.jit(make_client_eval(model))
    val_batches = stack_eval_split(model.cfg, clients, "val")
    km = jax.jit(kmeans, static_argnames=("k", "iters", "use_pallas"))
    agg = jax.jit(cluster_fedavg, static_argnames=("k",))
    n_samples = jnp.asarray([c["n_train"] for c in clients], jnp.float32)

    def round_fn(params, opt_state, key, np_rng):
        for _ in range(local_steps):
            xs, ys = [], []
            for c in clients:
                X, y = c["train"]
                i = np_rng.integers(0, len(y), size=batch_size)
                xs.append(X[i])
                ys.append(y[i])
            batch = make_batch(model.cfg, np.stack(xs), np.stack(ys))
            params, opt_state, _ = vstep(params, opt_state, batch, lr)
        val = np.asarray(veval(params, val_batches))
        feats = swarm_distribution_matrix(params, n_clients)
        _, a0 = km(key, feats, k=k, iters=kmeans_iters)
        plan = brain_storm(np_rng, np.asarray(a0), val, k, p1, p2)
        params = agg(params, jnp.asarray(plan.assignments), n_samples, k=k)
        return params, opt_state, float(val.mean())

    return round_fn


def fused_round_bench(n_clients: int = 14, data_scale: int = 8,
                      local_steps: int = 8, batch_size: int = 8,
                      rounds: int = 4, seed: int = 0,
                      out_json: str = "BENCH_round.json"):
    """Tentpole measurement (PR 2): one full BSO round as

      PR1  — the host-driven decomposition: a per-step numpy sampling
             loop feeding a vmapped train step, then the (already
             batched) coordinator phase as separate device programs +
             the numpy brain storm,
      PR2  — ONE jit'd ``swarm_round`` program (on-device sampling, jax
             brain storm, everything fused),
      scan — ``run_rounds``: the whole multi-round fit as one program.

    Writes ``BENCH_round.json`` with the three timings.
    """
    clinics = make_dr_swarm_data(image_size=16, seed=seed,
                                 table=scale_table(data_scale))
    clients = [clinics[i % len(clinics)] for i in range(n_clients)]
    model = build_model(get_config("squeezenet-dr"))
    opt = make_optimizer(OptimizerConfig(name="adam", lr=2e-3))
    lr = 2e-3

    # ---------------- PR1-style host-driven round ----------------
    pr1_round = make_host_loop_round(model, opt, clients,
                                     local_steps=local_steps,
                                     batch_size=batch_size, lr=lr)
    np_rng = np.random.default_rng(seed)

    # both sides re-initialise the swarm inside the timed region (the
    # engine path must: jit_swarm_round donates its state buffers)
    keys = jax.random.split(jax.random.PRNGKey(seed), n_clients)

    def pr1_full():
        params0 = jax.vmap(model.init)(keys)
        return pr1_round(params0, jax.vmap(opt.init)(params0),
                         jax.random.PRNGKey(seed + 1), np_rng)

    _, us_pr1 = timed(pr1_full, warmup=1, iters=3)
    row(f"round/pr1_host_loop_N{n_clients}", us_pr1,
        f"programs={local_steps + 4}+host_bsa")

    # ---------------- PR2: one fused program per round ----------------
    # local_unroll=local_steps for the single-round path: XLA CPU
    # executes while-loop bodies ~2x slower than the same ops unrolled
    # (a CPU-backend artifact; TPU keeps the rolled default). The
    # scanned fit keeps the rolled local phase — unrolling inside the
    # outer rounds-loop would re-pay the while penalty on a 8x body.
    data = make_swarm_data(model.cfg, clients)
    cfg = EngineConfig(model=model, opt=opt, local_steps=local_steps,
                       batch_size=batch_size, lr=lr, aggregation="bso",
                       n_clusters=3, p1=0.9, p2=0.8,
                       local_unroll=local_steps)
    cfg_rolled = EngineConfig(model=model, opt=opt, local_steps=local_steps,
                              batch_size=batch_size, lr=lr,
                              aggregation="bso", n_clusters=3,
                              p1=0.9, p2=0.8)

    def fused_round():
        state = make_swarm_state(model, opt, clients,
                                 jax.random.PRNGKey(seed))
        return jit_swarm_round(state, data, cfg)

    _, us_fused = timed(fused_round, warmup=1, iters=3)
    row(f"round/fused_engine_N{n_clients}", us_fused,
        f"programs=1;speedup={us_pr1 / us_fused:.2f}x")

    # ---------------- scan: one program for the whole fit ----------------
    def scanned_fit():
        state = make_swarm_state(model, opt, clients,
                                 jax.random.PRNGKey(seed))
        return jit_run_rounds(state, data, cfg_rolled, rounds)

    _, us_scan = timed(scanned_fit, warmup=1, iters=3)
    us_scan_round = us_scan / rounds
    row(f"round/scanned_fit_per_round_N{n_clients}", us_scan_round,
        f"programs=1/{rounds}rounds;speedup={us_pr1 / us_scan_round:.2f}x")

    artifact = {
        "n_clients": n_clients,
        "local_steps": local_steps,
        "batch_size": batch_size,
        "rounds_scanned": rounds,
        # pr1: one dispatch per local step + eval + stats + kmeans +
        # aggregation, plus the host-side numpy brain storm round-trip
        "programs_pr1_round": local_steps + 4,
        "programs_fused_round": 1,
        "us_pr1_host_round": us_pr1,
        "us_fused_round": us_fused,
        "us_scanned_fit_per_round": us_scan_round,
        "fused_speedup": us_pr1 / us_fused,
        "scanned_speedup": us_pr1 / us_scan_round,
        "note": "CPU-backend numbers. scanned_speedup < 1 is an "
                "XLA-CPU artifact, not a regression: the scanned fit "
                "keeps its local phase as a rolled lax.scan inside the "
                "rounds loop, and XLA's CPU backend executes ops in a "
                "while-loop body ~2x slower than the same ops unrolled "
                "(the single-round path unrolls via local_unroll, so "
                "it dodges the penalty). The transferable win is the "
                "dispatch-count collapse — one executable per fit — "
                "which on TPU, where per-dispatch overhead dominates, "
                "is also the wall-clock win. BENCH_sweep.json extends "
                "the same collapse across the Table-II method axis.",
    }
    with open(out_json, "w") as f:
        json.dump(artifact, f, indent=2)
    print(f"[fused_round_bench] wrote {out_json}: {artifact}")
    return artifact


if __name__ == "__main__":
    fused_round_bench()
    coordinator_bench()
    grid_bench()
    run()
