"""Beyond-paper ablation: cluster count k and brain-storm probabilities.

The paper fixes k=3, p1=0.9, p2=0.8 without ablation; this benchmark
sweeps them so the mechanism's contribution is measurable:
  * k=1 reduces BSO-SL to FedAvg (sanity anchor),
  * p1=p2=1.0 disables the brain-storm disruption entirely,
  * p1=p2=0.0 maximises disruption.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import row, timed
from repro.configs import get_config
from repro.configs.base import OptimizerConfig, SwarmConfig
from repro.core.diststats import (swarm_distribution_matrix,
                                  swarm_distribution_matrix_loop)
from repro.core.kmeans import kmeans
from repro.core.swarm import SwarmTrainer, eval_client
from repro.data.dr import TABLE_I, make_dr_swarm_data
from repro.models import build_model
from repro.utils.tree import tree_index, tree_paths_and_leaves

CASES = [
    ("k1_fedavg_like", dict(n_clusters=1)),
    ("k3_paper", dict(n_clusters=3)),
    ("k5", dict(n_clusters=5)),
    ("k3_no_brainstorm", dict(n_clusters=3, p1=1.0, p2=1.0)),
    ("k3_max_disruption", dict(n_clusters=3, p1=0.0, p2=0.0)),
]


def run(data_scale: int = 2, rounds: int = 6, local_steps: int = 10, seed: int = 0):
    table = np.maximum(TABLE_I // data_scale,
                       (TABLE_I > 0).astype(np.int64) * 2)
    clients = make_dr_swarm_data(image_size=20, seed=seed, table=table)
    model = build_model(get_config("squeezenet-dr"))
    out = {}
    for name, kw in CASES:
        swarm = SwarmConfig(n_clients=14, rounds=rounds,
                            local_steps=local_steps, **kw)
        t0 = time.time()
        tr = SwarmTrainer(model, clients, swarm,
                          OptimizerConfig(name="adam", lr=2e-3),
                          jax.random.PRNGKey(seed), batch_size=8,
                          aggregation="bso")
        tr.fit(jax.random.PRNGKey(seed + 1))
        acc = tr.mean_accuracy("test")
        events = sum(len(l.events) for l in tr.history)
        out[name] = acc
        row(f"ablation/{name}", (time.time() - t0) * 1e6,
            f"acc={acc:.4f};bso_events={events}")
    return out


def coordinator_bench(n_clients: int = 64, seed: int = 0):
    """Tentpole measurement: the per-round coordinator phase
    (distribution stats + k-means + eval) as a handful of fused device
    programs vs the old per-client host loops.

      old: N·T tiny stat dispatches + sum_i ceil(n_i/64) eval dispatches
      new: 1 stats program + 1 jit'd Lloyd loop + 1 vmapped eval program
    """
    model = build_model(get_config("squeezenet-dr"))
    keys = jax.random.split(jax.random.PRNGKey(seed), n_clients)
    params = jax.vmap(model.init)(keys)
    n_tensors = len(tree_paths_and_leaves(params))

    # --- distribution stats: host loop (old) vs single fused pass (new)
    _, us_old = timed(lambda: swarm_distribution_matrix_loop(
        params, n_clients), warmup=1, iters=3)
    row(f"coordinator/stats_loop_N{n_clients}", us_old,
        f"programs={n_clients * n_tensors}")
    _, us_new = timed(lambda: swarm_distribution_matrix(
        params, n_clients), warmup=1, iters=3)
    row(f"coordinator/stats_batched_N{n_clients}", us_new,
        f"programs=1;speedup={us_old / us_new:.1f}x")

    # --- k-means: eager Lloyd (old) vs one jit'd program (new)
    feats = jax.block_until_ready(swarm_distribution_matrix(params, n_clients))
    kkey = jax.random.PRNGKey(seed + 1)
    _, us_old = timed(lambda: kmeans(kkey, feats, 3, 20), warmup=1, iters=3)
    row(f"coordinator/kmeans_eager_N{n_clients}", us_old, "programs=O(iters)")
    km = jax.jit(kmeans, static_argnames=("k", "iters", "use_pallas"))
    _, us_new = timed(lambda: km(kkey, feats, k=3, iters=20),
                      warmup=1, iters=3)
    row(f"coordinator/kmeans_jit_N{n_clients}", us_new,
        f"programs=1;speedup={us_old / us_new:.1f}x")

    # --- eval + full round on an N-client swarm (clinics cycled to N)
    table = np.maximum(TABLE_I // 8, (TABLE_I > 0).astype(np.int64) * 2)
    clinics = make_dr_swarm_data(image_size=16, seed=seed, table=table)
    clients = [clinics[i % len(clinics)] for i in range(n_clients)]
    swarm = SwarmConfig(n_clients=n_clients, rounds=1, local_steps=1)
    tr = SwarmTrainer(model, clients, swarm,
                      OptimizerConfig(name="adam", lr=2e-3),
                      jax.random.PRNGKey(seed), batch_size=8,
                      aggregation="bso")

    def eval_loop():
        return [eval_client(tr._eval, tr.cfg, tree_index(tr.params, i),
                            *tr.data[i]["val"]) for i in range(n_clients)]

    n_batches = sum(-(-len(c["val"][1]) // 64) for c in tr.data)
    _, us_old = timed(eval_loop, warmup=1, iters=3)
    row(f"coordinator/eval_loop_N{n_clients}", us_old,
        f"programs={n_batches}")
    _, us_new = timed(lambda: tr.client_scores("val"), warmup=1, iters=3)
    row(f"coordinator/eval_vmapped_N{n_clients}", us_new,
        f"programs=1;speedup={us_old / us_new:.1f}x")

    key = jax.random.PRNGKey(seed + 2)
    _, us_round = timed(lambda: tr.round(0, key), warmup=1, iters=3)
    row(f"coordinator/full_bso_round_N{n_clients}", us_round,
        "stats+kmeans+eval+agg batched")
    return None


if __name__ == "__main__":
    coordinator_bench()
    run()
