"""Beyond-paper ablation: cluster count k and brain-storm probabilities.

The paper fixes k=3, p1=0.9, p2=0.8 without ablation; this benchmark
sweeps them so the mechanism's contribution is measurable:
  * k=1 reduces BSO-SL to FedAvg (sanity anchor),
  * p1=p2=1.0 disables the brain-storm disruption entirely,
  * p1=p2=0.0 maximises disruption.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import row
from repro.configs import get_config
from repro.configs.base import OptimizerConfig, SwarmConfig
from repro.core.swarm import SwarmTrainer
from repro.data.dr import TABLE_I, make_dr_swarm_data
from repro.models import build_model

CASES = [
    ("k1_fedavg_like", dict(n_clusters=1)),
    ("k3_paper", dict(n_clusters=3)),
    ("k5", dict(n_clusters=5)),
    ("k3_no_brainstorm", dict(n_clusters=3, p1=1.0, p2=1.0)),
    ("k3_max_disruption", dict(n_clusters=3, p1=0.0, p2=0.0)),
]


def run(data_scale: int = 2, rounds: int = 6, local_steps: int = 10, seed: int = 0):
    table = np.maximum(TABLE_I // data_scale,
                       (TABLE_I > 0).astype(np.int64) * 2)
    clients = make_dr_swarm_data(image_size=20, seed=seed, table=table)
    model = build_model(get_config("squeezenet-dr"))
    out = {}
    for name, kw in CASES:
        swarm = SwarmConfig(n_clients=14, rounds=rounds,
                            local_steps=local_steps, **kw)
        t0 = time.time()
        tr = SwarmTrainer(model, clients, swarm,
                          OptimizerConfig(name="adam", lr=2e-3),
                          jax.random.PRNGKey(seed), batch_size=8,
                          aggregation="bso")
        tr.fit(jax.random.PRNGKey(seed + 1))
        acc = tr.mean_accuracy("test")
        events = sum(len(l.events) for l in tr.history)
        out[name] = acc
        row(f"ablation/{name}", (time.time() - t0) * 1e6,
            f"acc={acc:.4f};bso_events={events}")
    return out


if __name__ == "__main__":
    run()
