"""Ragged-layout benchmark (PR 6): bucketed clients vs the rectangular
pad-to-max layout, as the SAME scanned ``run_rounds`` fit.

Table I's clinic sizes span 14..974 images, so the rectangular
``SwarmData`` layout stores every clinic padded to the largest one —
~70% of train rows are poison pads at unit scale. ``BucketedSwarmData``
groups clinics into power-of-two size buckets (pad only to the bucket
ceiling) and the engine runs one gather per bucket inside the identical
round program, so the fit itself stays ONE executable per layout.

The parity oracle is bitwise: both layouts draw the identical
``(N, batch)`` index tensor and evaluate the identical microbatch
prefix, so ``run_rounds`` must produce bit-identical params and
metrics. Writes ``BENCH_bucket.json`` with the pad accounting, the
wall-clocks, and the parity check.
"""
from __future__ import annotations

import json

import jax
import numpy as np

from benchmarks.common import row, timed
from repro.configs import get_config
from repro.configs.base import OptimizerConfig
from repro.core.engine import (EngineConfig, jit_run_rounds,
                               make_bucketed_swarm_data, make_swarm_data,
                               make_swarm_state, pad_fraction)
from repro.data.dr import make_dr_swarm_data, scale_table
from repro.models import build_model
from repro.optim.optimizers import make_optimizer


def run(data_scale: int = 4, rounds: int = 2, local_steps: int = 4,
        image_size: int = 16, seed: int = 0, max_buckets: int = 4,
        batch_size: int = 8, eval_batch: int = 8,
        out_json: str | None = "BENCH_bucket.json"):
    """Both layouts through the identical ``jit_run_rounds`` fit.

    ``eval_batch=8`` keeps the eval-stack quantum small enough that the
    bucket ceilings (not the microbatch rounding) dominate the stored
    eval rows at benchmark scale — the same knob the engine exposes.
    """
    clients = make_dr_swarm_data(image_size=image_size, seed=seed,
                                 table=scale_table(data_scale))
    model = build_model(get_config("squeezenet-dr"))
    opt = make_optimizer(OptimizerConfig(name="adam", lr=2e-3))
    cfg = EngineConfig(model=model, opt=opt, local_steps=local_steps,
                       batch_size=batch_size, lr=2e-3, aggregation="bso",
                       n_clusters=3, p1=0.9, p2=0.8, kmeans_iters=10)

    rect = make_swarm_data(model.cfg, clients, eval_batch=eval_batch)
    buck = make_bucketed_swarm_data(model.cfg, clients,
                                    eval_batch=eval_batch,
                                    max_buckets=max_buckets)
    pf_rect = pad_fraction(rect)
    pf_buck = pad_fraction(buck)
    reduction = ((pf_rect["stored_rows"] - pf_rect["real_rows"])
                 / max(pf_buck["stored_rows"] - pf_buck["real_rows"], 1))
    row("bucket/pad_rows_rect", 0.0,
        f"train={pf_rect['train']:.3f};total={pf_rect['total']:.3f};"
        f"stored={pf_rect['stored_rows']}")
    row("bucket/pad_rows_bucketed", 0.0,
        f"train={pf_buck['train']:.3f};total={pf_buck['total']:.3f};"
        f"stored={pf_buck['stored_rows']};buckets={len(buck.client_ids)}")
    row("bucket/pad_reduction", 0.0, f"pad_rows_x={reduction:.2f}")

    # state rebuilt inside each timed closure: jit_run_rounds donates
    def fit_rect():
        state = make_swarm_state(model, opt, clients,
                                 jax.random.PRNGKey(seed))
        return jit_run_rounds(state, rect, cfg, rounds)

    def fit_buck():
        state = make_swarm_state(model, opt, clients,
                                 jax.random.PRNGKey(seed))
        return jit_run_rounds(state, buck, cfg, rounds)

    (st_r, ms_r), us_rect = timed(fit_rect, warmup=1, iters=3)
    row(f"bucket/fit_rect_r{rounds}", us_rect, "programs=1")
    (st_b, ms_b), us_buck = timed(fit_buck, warmup=1, iters=3)
    row(f"bucket/fit_bucketed_r{rounds}", us_buck,
        f"programs=1;speedup={us_rect / us_buck:.2f}x")

    acc_diff = float(np.max(np.abs(np.asarray(ms_r.val_acc)
                                   - np.asarray(ms_b.val_acc))))
    params_bitwise = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(st_r.params),
                        jax.tree.leaves(st_b.params)))
    row("bucket/parity", 0.0,
        f"max_abs_acc_diff={acc_diff:.2e};params_bitwise={params_bitwise}")

    artifact = {
        "n_clients": len(clients),
        "data_scale": data_scale,
        "image_size": image_size,
        "rounds": rounds,
        "local_steps": local_steps,
        "batch_size": batch_size,
        "eval_batch": eval_batch,
        "max_buckets": max_buckets,
        "buckets": [list(map(int, ids)) for ids in buck.client_ids],
        "bucket_train_ceilings": [
            int(jax.tree.leaves(t)[0].shape[1]) for t in buck.train],
        "pad_fraction_rect": pf_rect,
        "pad_fraction_bucketed": pf_buck,
        "pad_rows_reduction_x": reduction,
        "us_rect_fit": us_rect,
        "us_bucket_fit": us_buck,
        "parity_max_abs_acc_diff": acc_diff,
        "params_bitwise": params_bitwise,
        "note": "Both fits are ONE jit_run_rounds executable; the "
                "bucketed layout swaps the single (N, n_max) gather "
                "for one gather per bucket inside the same program. "
                "Parity is bitwise by construction (identical "
                "(N, batch) index draw, identical microbatch prefix), "
                "so params_bitwise must be true and acc_diff 0.0. The "
                "transferable win is the stored-pad-row collapse "
                "(pad_rows_reduction_x): at unit Table-I scale the "
                "rectangular train stack is ~70% poison pads; CPU "
                "wall-clock gains are modest because XLA re-pads "
                "ragged gathers into per-bucket convs, but on "
                "memory-bound accelerators the stored-row footprint "
                "IS the constraint.",
    }
    if out_json:
        with open(out_json, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"[bucket_bench] wrote {out_json}")
    return artifact


def main() -> None:
    run()


if __name__ == "__main__":
    main()
