"""Aggregate the dry-run artifacts into the §Roofline table.

Reads benchmarks/artifacts/dryrun_*.json (produced by
repro.launch.dryrun) and prints the per-(arch x shape x mesh) roofline
terms, dominant bottleneck, and useful-flops ratio. Also emits the
markdown table pasted into EXPERIMENTS.md.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import row

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"


def load_all():
    recs = []
    for p in sorted(ARTIFACTS.glob("dryrun_*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def main(markdown: bool = False):
    recs = load_all()
    if not recs:
        row("roofline/no_artifacts", 0.0,
            "run `python -m repro.launch.dryrun` first")
        return
    lines = ["| arch | shape | mesh | peak GiB/dev | Tc (s) | Tm (s) | "
             "Tcoll (s) | dominant | useful |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if not r.get("ok"):
            continue
        rf = r["roofline"]
        peak = r["memory"]["peak_per_device"] / 2 ** 30
        step_s = max(rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"])
        row(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", step_s * 1e6,
            f"Tc={rf['t_compute_s']:.3e};Tm={rf['t_memory_s']:.3e};"
            f"Tcoll={rf['t_collective_s']:.3e};dom={rf['dominant']};"
            f"useful={rf['useful_flops_ratio']:.3f};peakGiB={peak:.2f}")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {peak:.2f} | "
            f"{rf['t_compute_s']:.3e} | {rf['t_memory_s']:.3e} | "
            f"{rf['t_collective_s']:.3e} | {rf['dominant']} | "
            f"{rf['useful_flops_ratio']:.2f} |")
    if markdown:
        print("\n".join(lines))


if __name__ == "__main__":
    import sys
    main(markdown="--markdown" in sys.argv)
