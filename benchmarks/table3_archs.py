"""Paper Table III: BSO-SL with AlexNet / VGG / Inception / SqueezeNet
local models — the model-agnostic sweep (RQ2).

Rebuilt on the sweep engine: one device-resident ``SwarmData`` is
built once and shared by every architecture, and each arch's whole fit
is ONE scanned ``run_method`` program (the serial slice of
``run_sweep`` — the method axis itself can't batch across archs, whose
param pytrees differ in shape).
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import row
from repro.configs import get_config
from repro.configs.base import OptimizerConfig, SwarmConfig
from repro.core.baselines import make_method_setup, run_method
from repro.data.dr import make_dr_swarm_data, scale_table
from repro.models import build_model

ARCHS = ["alexnet-dr", "vgg-dr", "inception-dr", "squeezenet-dr"]
PAPER = {"alexnet-dr": 0.3703, "vgg-dr": 0.4016, "inception-dr": 0.4216,
         "squeezenet-dr": 0.3725}


def run(data_scale: int = 1, rounds: int = 8, local_steps: int = 12,
        image_size: int = 20, seed: int = 0):
    clients = make_dr_swarm_data(image_size=image_size, seed=seed,
                                 table=scale_table(data_scale))
    swarm = SwarmConfig(n_clients=14, n_clusters=3, rounds=rounds,
                        local_steps=local_steps)
    opt = OptimizerConfig(name="adam", lr=2e-3)
    results, data = {}, None
    for arch in ARCHS:
        model = build_model(get_config(arch))
        cfg, data = make_method_setup(model, clients, swarm, opt,
                                      batch_size=8, data=data)
        n = model.param_count(model.init(jax.random.PRNGKey(0)))
        t0 = time.time()
        acc, _ = run_method("bso-sl", model, clients, swarm, opt,
                            jax.random.PRNGKey(seed), batch_size=8,
                            cfg=cfg, data=data)
        results[arch] = acc
        row(f"table3/{arch}", (time.time() - t0) * 1e6,
            f"acc={acc:.4f};paper_acc={PAPER[arch]:.4f};params={n};"
            f"programs=1")
    return results


def main():
    results = run()
    # model-agnostic claim: every architecture trains under BSO-SL
    all_learn = all(a > 0.15 for a in results.values())
    row("table3/model_agnostic_check", 0.0, f"all_archs_learn={all_learn}")


if __name__ == "__main__":
    main()
