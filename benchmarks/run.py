"""Benchmark harness entry point — one module per paper table/claim.

  table2_methods   — paper Table II  (4 methods on the DR task)
  table3_archs     — paper Table III (model-agnostic CNN sweep)
  comm_scaling     — §I/§III.B scalability & communication claim
  cluster_ablation — beyond-paper k / p1 / p2 ablation
  churn_bench      — dropout x stale-decay robustness sweep (one program)
  hier_bench       — two-tier coordination: O(pods) upload scaling + the
                     pods==1 bitwise anchor (BENCH_hier.json)
  bucket_bench     — ragged bucketed layout vs rectangular pad-to-max
  kernel_bench     — kernel-layer microbenchmarks
  roofline_report  — §Roofline table from the dry-run artifacts
  serve_bench      — continuous-batching engine: throughput/latency vs
                     bucket layout + the per-bucket program budget

Each row prints ``name,us_per_call,derived`` CSV.
Usage: PYTHONPATH=src python -m benchmarks.run [--only name] [--fast]
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--fast", action="store_true",
                    help="smaller data scale for quick runs")
    ap.add_argument("--quick", action="store_true",
                    help="sweep + grid smoke only: the Table-II method "
                         "axis as one run_sweep program and the k x p1 "
                         "ablation as one run_grid program, both at "
                         "--data-scale CPU size")
    ap.add_argument("--data-scale", type=int, default=16,
                    help="Table-I divisor for --quick/--fast runs")
    ap.add_argument("--no-artifacts", action="store_true",
                    help="never (re)write BENCH_*.json — the CI smoke "
                         "guard (--quick already writes none; this also "
                         "covers the full/--fast suites)")
    args = ap.parse_args()

    if args.quick:
        from benchmarks import (churn_bench, cluster_ablation, hier_bench,
                                serve_bench, table2_methods)
        print("name,us_per_call,derived")
        table2_methods.run(data_scale=args.data_scale, rounds=2,
                           local_steps=2, image_size=16,
                           serial_reference=False)
        cluster_ablation.grid_bench(data_scale=args.data_scale, rounds=2,
                                    local_steps=2, serial_reference=False,
                                    out_json=None)
        churn_bench.run(data_scale=args.data_scale, rounds=2,
                        local_steps=2, dropouts=(0.0, 0.4),
                        stale_decays=(0.0, 0.5), out_json=None)
        serve_bench.run(n_requests=6, max_new=4, max_seq=32, slots=4,
                        cnn_requests=6, cnn_buckets=(1, 4), out_json=None)
        # two-tier smoke: small Ns, same invariants (O(pods) slope vs
        # ledger, pods==1 bitwise, compile census), no artifact
        hier_bench.run(ns=(128, 256), pod_size=32, rounds=2,
                       local_steps=2, out_json=None)
        return

    from benchmarks import (bucket_bench, churn_bench, cluster_ablation,
                            comm_scaling, hier_bench, kernel_bench,
                            roofline_report, serve_bench, table2_methods,
                            table3_archs)

    suites = {
        "comm_scaling": comm_scaling.main,
        "kernel_bench": kernel_bench.main,
        "roofline_report": roofline_report.main,
        "table2_methods": table2_methods.main,
        "table3_archs": table3_archs.main,
        "cluster_ablation": lambda: (cluster_ablation.grid_bench(),
                                     cluster_ablation.run()),
        "churn_bench": churn_bench.main,
        "bucket_bench": bucket_bench.main,
        "hier_bench": hier_bench.main,
        "serve_bench": serve_bench.main,
    }
    if args.fast:
        scale = args.data_scale
        suites["table2_methods"] = lambda: table2_methods.run(
            data_scale=scale, rounds=2, local_steps=4)
        suites["table3_archs"] = lambda: table3_archs.run(
            data_scale=scale, rounds=2, local_steps=4)
        suites["cluster_ablation"] = lambda: (
            cluster_ablation.grid_bench(data_scale=scale, rounds=2,
                                        local_steps=4, out_json=None),
            cluster_ablation.run(data_scale=scale, rounds=2, local_steps=4))
        suites["churn_bench"] = lambda: churn_bench.run(
            data_scale=scale, rounds=2, local_steps=4,
            dropouts=(0.0, 0.4), stale_decays=(0.0, 0.5), out_json=None)
        suites["bucket_bench"] = lambda: bucket_bench.run(
            data_scale=scale, rounds=2, local_steps=4, out_json=None)
        suites["hier_bench"] = lambda: hier_bench.run(
            ns=(128, 256), pod_size=32, rounds=2, local_steps=4,
            out_json=None)
        suites["serve_bench"] = lambda: serve_bench.run(
            n_requests=8, max_new=4, max_seq=32, slots=4,
            cnn_requests=8, out_json=None)
    if args.no_artifacts and not args.fast:
        # --fast is already write-free (its overrides above pass
        # bench_json/out_json=None); only the full suite's writers —
        # table2_methods.main (BENCH_sweep.json), the default grid_bench
        # (BENCH_grid.json), churn_bench (BENCH_churn.json), bucket_bench
        # (BENCH_bucket.json), hier_bench (BENCH_hier.json) and
        # serve_bench (BENCH_serve.json) — need the artifact-free
        # variant of the SAME measurement
        suites["table2_methods"] = lambda: table2_methods.run(
            paper_budget_oracle=True)
        suites["cluster_ablation"] = lambda: (
            cluster_ablation.grid_bench(out_json=None),
            cluster_ablation.run())
        suites["churn_bench"] = lambda: churn_bench.run(out_json=None)
        suites["bucket_bench"] = lambda: bucket_bench.run(out_json=None)
        suites["hier_bench"] = lambda: hier_bench.run(out_json=None)
        suites["serve_bench"] = lambda: serve_bench.run(out_json=None)

    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        try:
            fn()
            print(f"suite/{name},{(time.time()-t0)*1e6:.0f},status=ok")
        except Exception as e:  # noqa: BLE001
            print(f"suite/{name},{(time.time()-t0)*1e6:.0f},status=FAIL:{e!r}")
            raise


if __name__ == '__main__':
    main()
