"""Kernel-layer microbenchmarks.

On this CPU container, Pallas kernels execute in interpret mode —
wall-times are NOT TPU-representative; what is representative (and
recorded) is the oracle-path timing and each kernel's arithmetic
intensity, which feed the §Roofline discussion. interpret-mode timings
are emitted with an explicit 'interpret=1' tag so nobody mistakes them
for device numbers.
"""
from __future__ import annotations

import jax

from benchmarks.common import row, timed
from repro.kernels import ops, ref


def main():
    key = jax.random.PRNGKey(0)
    B, H, KV, S, D = 1, 8, 2, 1024, 64
    q = jax.random.normal(key, (B, H, S, D))
    k = jax.random.normal(key, (B, KV, S, D))
    v = jax.random.normal(key, (B, KV, S, D))

    fa_ref = jax.jit(lambda q, k, v: ref.ref_attention(q, k, v, causal=True))
    _, us = timed(fa_ref, q, k, v)
    flops = 4 * B * H * S * S * D / 2
    row("kernel/attention_ref_jit", us,
        f"S={S};flops={flops:.3e};interpret=0")

    qd = jax.random.normal(key, (B, H, 1, D))
    kc = jax.random.normal(key, (B, KV, 8192, D))
    vc = jax.random.normal(key, (B, KV, 8192, D))
    fd_ref = jax.jit(lambda q, k, v: ref.ref_decode_attention(q, k, v, 8000))
    _, us = timed(fd_ref, qd, kc, vc)
    bytes_ = 2 * B * KV * 8192 * D * 4
    row("kernel/decode_ref_jit", us,
        f"cache=8192;bytes={bytes_:.3e};ai={2*D/ (2*4):.1f}flop_per_B;interpret=0")

    x = jax.random.normal(key, (1 << 20,))
    ps_ref = jax.jit(lambda x: ref.ref_param_stats(x))
    _, us = timed(ps_ref, x)
    row("kernel/param_stats_ref_jit", us,
        f"elems={x.size};bytes={x.size*4:.3e};interpret=0")

    # client-batched swarm reduction: one program for all 64 clients vs
    # 64 per-client dispatches (the coordinator's old hot path)
    xs = jax.random.normal(key, (64, 1 << 16))
    psb_ref = jax.jit(ref.ref_param_stats_batched)
    _, us_b = timed(psb_ref, xs)
    row("kernel/param_stats_batched64_ref_jit", us_b,
        f"N=64;elems={xs.size};programs=1;interpret=0")
    ps_one = jax.jit(ref.ref_param_stats)
    _, us_l = timed(lambda: [ps_one(xs[i]) for i in range(64)],
                    warmup=1, iters=3)
    row("kernel/param_stats_loop64_ref_jit", us_l,
        f"N=64;programs=64;slowdown={us_l / us_b:.1f}x;interpret=0")

    # interpret-mode (correctness-path) timings for completeness
    _, us = timed(lambda: ops.param_stats(x), warmup=1, iters=2)
    row("kernel/param_stats_pallas_interp", us, "interpret=1")
    _, us = timed(lambda: ops.param_stats_batched(xs), warmup=1, iters=2)
    row("kernel/param_stats_batched64_pallas_interp", us, "interpret=1")
    Xs = jax.random.normal(key, (256, 64))
    Cs = jax.random.normal(key, (3, 64))
    _, us = timed(lambda: ops.kmeans_assign(Xs, Cs), warmup=1, iters=2)
    row("kernel/kmeans_assign_pallas_interp", us, "interpret=1")


if __name__ == "__main__":
    main()
