"""Two-tier coordination benchmark (PR 9): host-facing bytes and
coordinator wall vs swarm size, flat vs hierarchical — the O(pods)
scaling claim behind ``BENCH_hier.json``.

Three measurements:

* **Upload scaling** — the pod tier (``engine.pod_summaries``) runs as
  ONE jit'd program over synthetic client stats at N up to 4096 (fixed
  pod size, so pods grow with N) and the bytes that actually face the
  host are the summary arrays' device nbytes. Checked against the
  analytical ledger (``comm.hier_host_bytes``) within 15% per point,
  with the log-log slope vs pod count pinned ~1 (O(pods), while the
  flat upload is O(clients)); ``comm.hier_scaling_table`` extrapolates
  the same arithmetic to N = 10^4..10^6.
* **Coordinator wall** — ``host_coordinator`` on (N, F) stats vs
  ``host_hier_coordinator`` on the (pods * k_local, F) summaries: host
  compute drops from O(clients) to O(pods) per round.
* **Protocol anchors** — ``pods == 1`` hier ``run_rounds`` reproduces
  the flat coordinator BITWISE (the HierParams short-circuit), the
  multi-pod hier fit stays a working learner whose final val accuracy
  sits near the flat oracle at small N, and both hier fits cost ONE
  ``jit_run_rounds`` program each (compile census).

CPU wall-clocks are trend indicators; the bytes and the census are
exact.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.configs import get_config
from repro.configs.base import OptimizerConfig
from repro.core.engine import (EngineConfig, hier_params, jit_run_rounds,
                               make_swarm_data, make_swarm_state,
                               pod_summaries)
from repro.core.diststats import upload_bytes
from repro.data.dr import TABLE_I, make_dr_swarm_data
from repro.launch.comm import hier_host_bytes, hier_scaling_table
from repro.launch.fleet_driver import host_coordinator, host_hier_coordinator
from repro.models import build_model
from repro.optim.optimizers import make_optimizer

#: fixed pod size for the scaling axis — pods grow with N
POD_SIZE = 64
NS = (256, 1024, 4096)
K_LOCAL = 2


def _params_abs():
    model = build_model(get_config("squeezenet-dr"))
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def _scaling_point(N: int, pod_size: int, k_local: int, F: int,
                   kmeans_iters: int, seed: int = 0):
    """One N on the scaling axis: jit the pod tier over synthetic
    (N, F) stats, measure the host-facing nbytes and the program wall.
    Returns the artifact row."""
    P = N // pod_size
    hp = hier_params(N, P, k_local=k_local)
    key = jax.random.PRNGKey(seed)
    feats = jax.random.normal(jax.random.fold_in(key, 1), (N, F),
                              jnp.float32)
    val = jax.random.uniform(jax.random.fold_in(key, 2), (N,), jnp.float32)
    weights = jnp.ones((N,), jnp.float32)

    fn = jax.jit(lambda f, v, w, k_: pod_summaries(
        f, v, w, None, k_local, kmeans_iters, k_, hp.pods))
    t0 = time.perf_counter()
    C, counts, wsums, valsums, _pc = jax.block_until_ready(
        fn(feats, val, weights, key))
    wall_first = time.perf_counter() - t0
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn(feats, val, weights, key))
    wall_steady = time.perf_counter() - t0

    # what actually faces the host per round: summaries up (a_local and
    # the fallback stay on device), vs the flat (N, F) stats + (N,) val
    hier_bytes = int(C.nbytes + counts.nbytes + wsums.nbytes
                     + valsums.nbytes)
    flat_bytes = int(feats.nbytes + val.nbytes)

    # coordinator wall, flat vs hier, on the same uploaded material
    # (warm call after a compile-absorbing first call)
    stats_h, val_h = np.asarray(feats), np.asarray(val)
    host_coordinator(stats_h, val_h, k=3, p1=0.9, p2=0.8,
                     kmeans_iters=kmeans_iters, seed=seed)
    t0 = time.perf_counter()
    host_coordinator(stats_h, val_h, k=3, p1=0.9, p2=0.8,
                     kmeans_iters=kmeans_iters, seed=seed)
    flat_coord_s = time.perf_counter() - t0
    Ch, ch, vh = np.asarray(C), np.asarray(counts), np.asarray(valsums)
    host_hier_coordinator(Ch, ch, vh, k=3, p1=0.9, p2=0.8,
                          kmeans_iters=kmeans_iters, seed=seed)
    t0 = time.perf_counter()
    host_hier_coordinator(Ch, ch, vh, k=3, p1=0.9, p2=0.8,
                          kmeans_iters=kmeans_iters, seed=seed)
    hier_coord_s = time.perf_counter() - t0
    return {
        "n_clients": N, "n_pods": P, "summary_rows": P * k_local,
        "hier_upload_bytes_measured": hier_bytes,
        "flat_upload_bytes_measured": flat_bytes,
        "pod_tier_wall_first_s": wall_first,
        "pod_tier_wall_steady_s": wall_steady,
        "flat_coord_wall_s": flat_coord_s,
        "hier_coord_wall_s": hier_coord_s,
    }


def _engine_anchor(rounds: int, local_steps: int, seed: int = 0):
    """pods==1 bitwise anchor + the multi-pod acc delta + compile
    census, at unit scale on the sim engine."""
    n_clients = 14
    table = np.maximum(TABLE_I // 16,
                       (TABLE_I > 0).astype(np.int64) * 2)[:, :n_clients]
    clients = make_dr_swarm_data(image_size=16, seed=seed, table=table)
    model = build_model(get_config("squeezenet-dr"))
    opt = make_optimizer(OptimizerConfig(name="adam", lr=2e-3))
    cfg = EngineConfig(model=model, opt=opt, local_steps=local_steps,
                       batch_size=8, lr=2e-3, aggregation="bso",
                       n_clusters=3, p1=0.9, p2=0.8, kmeans_iters=10)
    data = make_swarm_data(model.cfg, clients)

    def fit(hier):
        state = make_swarm_state(model, opt, clients,
                                 jax.random.PRNGKey(seed))
        return jit_run_rounds(state, data, cfg, rounds, hier=hier)

    n0 = jit_run_rounds._cache_size()
    s_flat, m_flat = fit(None)
    s_p1, _ = fit(hier_params(len(clients), 1))
    s_hier, m_hier = fit(hier_params(len(clients), 4, k_local=K_LOCAL))
    n_programs = jit_run_rounds._cache_size() - n0

    bitwise = all(
        bool(np.array_equal(np.asarray(x), np.asarray(y)))
        for x, y in zip(jax.tree.leaves(s_flat.params),
                        jax.tree.leaves(s_p1.params)))
    acc_flat = float(np.asarray(m_flat.mean_val_acc)[-1])
    acc_hier = float(np.asarray(m_hier.mean_val_acc)[-1])
    return {
        "n_clients": len(clients), "rounds": rounds,
        "pods1_bitwise_vs_flat": bitwise,
        "final_val_flat": acc_flat,
        "final_val_hier_4pods": acc_hier,
        "final_val_delta": acc_hier - acc_flat,
        # flat / pods==1 / 4-pod hier: each static hier value is ONE
        # whole-fit executable (the pods==1 entry traces the flat body
        # — bitwise — under its own cache key)
        "run_rounds_programs": n_programs,
    }


def run(pod_size: int = POD_SIZE, k_local: int = K_LOCAL, ns=NS,
        kmeans_iters: int = 10, rounds: int = 3, local_steps: int = 4,
        seed: int = 0, out_json: str = "BENCH_hier.json"):
    params_abs = _params_abs()
    F = upload_bytes(params_abs) // 4      # stat row width (f32 entries)

    points = []
    for N in ns:
        pt = _scaling_point(N, pod_size, k_local, F, kmeans_iters,
                            seed=seed)
        ledger = hier_host_bytes(params_abs, N, pt["n_pods"], k_local)
        pt["hier_upload_bytes_ledger"] = ledger["summary_upload_bytes"]
        pt["flat_upload_bytes_ledger"] = ledger["flat_upload_bytes"]
        pt["ledger_rel_err"] = abs(
            pt["hier_upload_bytes_measured"]
            - ledger["summary_upload_bytes"]) \
            / ledger["summary_upload_bytes"]
        points.append(pt)
        row(f"hier/scaling_N{N}", pt["pod_tier_wall_steady_s"] * 1e6,
            f"pods={pt['n_pods']};hier_B={pt['hier_upload_bytes_measured']}"
            f";flat_B={pt['flat_upload_bytes_measured']}"
            f";rel_err={pt['ledger_rel_err']:.3f}")

    # measured log-log slope of hier upload bytes vs pod count — O(pods)
    # means slope ~1 (each new pod adds one fixed-size summary block)
    lp = np.log([p["n_pods"] for p in points])
    lb = np.log([p["hier_upload_bytes_measured"] for p in points])
    slope = float(np.polyfit(lp, lb, 1)[0]) if len(points) > 1 else 1.0
    within = all(p["ledger_rel_err"] <= 0.15 for p in points)
    red = points[-1]["flat_upload_bytes_measured"] \
        / points[-1]["hier_upload_bytes_measured"]
    row("hier/upload_slope_vs_pods", 0.0,
        f"slope={slope:.3f};ledger_within_15pct={within};"
        f"reduction_at_N{points[-1]['n_clients']}={red:.1f}x")

    anchor = _engine_anchor(rounds, local_steps, seed=seed)
    row("hier/pods1_bitwise", 0.0,
        f"equal={anchor['pods1_bitwise_vs_flat']};"
        f"programs={anchor['run_rounds_programs']}")
    row("hier/small_n_acc", 0.0,
        f"flat={anchor['final_val_flat']:.4f};"
        f"hier={anchor['final_val_hier_4pods']:.4f};"
        f"delta={anchor['final_val_delta']:+.4f}")

    artifact = {
        "pod_size": pod_size,
        "k_local": k_local,
        "stat_width": F,
        "kmeans_iters": kmeans_iters,
        "points": points,
        "upload_slope_vs_pods": slope,
        "ledger_within_15pct": within,
        "extrapolation": hier_scaling_table(params_abs, pod_size=pod_size,
                                            k_local=k_local),
        "anchor": anchor,
        "note": "Upload bytes are the device nbytes of the pod-tier "
                "summary arrays (engine.pod_summaries as one jit'd "
                "program over synthetic (N, F) stats, F = the "
                "squeezenet-dr stat width) vs the flat (N, F) stats + "
                "(N,) val pull; the ledger comparison and the "
                "extrapolation rows are comm.hier_host_bytes / "
                "comm.hier_scaling_table arithmetic on the same "
                "abstract params. Coordinator walls time the warm host "
                "k-means+brain_storm calls on the same material. The "
                "anchor block runs the sim engine at unit scale: "
                "pods==1 routes to the flat coordinator verbatim "
                "(bitwise), the 4-pod fit reports its final-val delta "
                "vs the flat oracle, and the compile census counts "
                "jit_run_rounds entries (one whole-fit program per "
                "static hier value — never one per round). CPU "
                "wall-clocks are trend indicators, not paper numbers.",
    }
    if out_json:
        with open(out_json, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"[hier_bench] wrote {out_json}")
    return artifact


def main():
    return run()


if __name__ == "__main__":
    main()
