"""Paper Table II: centralized / local / FedAvg / BSO-SL on the DR task.

Runs all four methods on the Table-I-exact synthetic dataset (scaled by
--data-scale for CPU) and reports mean per-client test accuracy (Eq. 3).
The validation target is the paper's qualitative ordering:
centralized > {FedAvg ~ BSO-SL} > local.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import row
from repro.configs import get_config
from repro.configs.base import OptimizerConfig, SwarmConfig
from repro.core.baselines import run_method
from repro.data.dr import TABLE_I, make_dr_swarm_data
from repro.models import build_model

METHODS = ["centralized", "local", "fedavg", "bso-sl"]
PAPER = {"centralized": 0.4118, "local": 0.1924, "fedavg": 0.3719,
         "bso-sl": 0.3725}


def run(data_scale: int = 1, rounds: int = 10, local_steps: int = 12,
        image_size: int = 20, seed: int = 0, verbose: bool = False):
    table = np.maximum(TABLE_I // data_scale,
                       (TABLE_I > 0).astype(np.int64) * 2)
    clients = make_dr_swarm_data(image_size=image_size, seed=seed, table=table)
    model = build_model(get_config("squeezenet-dr"))
    swarm = SwarmConfig(n_clients=14, n_clusters=3, rounds=rounds,
                        local_steps=local_steps)
    opt = OptimizerConfig(name="adam", lr=2e-3)

    results = {}
    for method in METHODS:
        t0 = time.time()
        acc, _ = run_method(method, model, clients, swarm, opt,
                            jax.random.PRNGKey(seed), batch_size=8,
                            verbose=verbose)
        dt = time.time() - t0
        results[method] = acc
        row(f"table2/{method}", dt * 1e6,
            f"acc={acc:.4f};paper_acc={PAPER[method]:.4f}")
    return results


def main():
    results = run()
    # Validated qualitative claims (see EXPERIMENTS.md §Paper-results for
    # why the paper's local-baseline ordering is not reproducible with a
    # competent local trainer under the per-client Eq.3 protocol):
    #   (1) centralized upper-bounds the federated methods,
    #   (2) BSO-SL >= FedAvg (clustered aggregation handles label skew),
    #   (3) both federated methods clear the 5-class random floor.
    ok = (results["centralized"] >= results["bso-sl"] and
          results["bso-sl"] >= results["fedavg"] - 0.02 and
          results["bso-sl"] > 0.25 and results["fedavg"] > 0.2)
    row("table2/ordering_check", 0.0, f"validated_claims_hold={ok}")


if __name__ == "__main__":
    main()
