"""Paper Table II: centralized / local / FedAvg / BSO-SL on the DR task.

Rebuilds the whole method axis as ONE vmapped ``run_sweep`` program
(all four methods share a single device-resident SwarmData), then runs
the serial ``run_method`` slices as the parity + wall-clock reference.
The old benchmark dispatched 4 methods x rounds separate round
programs (plus the centralized host loop); the sweep is one lowered
executable — the collapse recorded in ``BENCH_sweep.json``.

Reports mean per-client test accuracy (Eq. 3). The validation target
is the paper's qualitative ordering:
centralized > {FedAvg ~ BSO-SL} > local.
"""
from __future__ import annotations

import json
import time

import jax

import numpy as np

from benchmarks.common import row
from repro.configs import get_config
from repro.configs.base import OptimizerConfig, SwarmConfig
from repro.core.baselines import (make_method_setup, run_method,
                                  run_sweep_table, sweep_keys,
                                  train_centralized)
from repro.core.engine import SWEEP_METHODS, stack_eval_split
from repro.data.dr import make_dr_swarm_data, scale_table
from repro.models import build_model

METHODS = list(SWEEP_METHODS)
PAPER = {"centralized": 0.4118, "local": 0.1924, "fedavg": 0.3719,
         "bso-sl": 0.3725}

#: Slack on the qualitative Table-II ordering checks. The per-client
#: Eq. 3 protocol averages 14 tiny clinic test splits (some a handful
#: of images), so one flipped image on a 5-image split moves a
#: method's mean acc by ~0.014 — orderings within that noise band are
#: ties, not violations. 0.02 is one such flip plus margin; it also
#: absorbs the documented local-overfit caveat (tiny non-IID clinics
#: reward local memorisation under Eq. 3, which compresses the
#: centralized-vs-federated gap the paper reports at full data scale).
#: See ROADMAP.md's noise-calibration note before tightening.
ORDERING_TOL = 0.02


def run(data_scale: int = 1, rounds: int = 10, local_steps: int = 12,
        image_size: int = 20, seed: int = 0, verbose: bool = False,
        serial_reference: bool = True, paper_budget_oracle: bool = False,
        bench_json: str = None):
    """Returns {method: Eq.3 test acc} from the one-program sweep.

    ``serial_reference`` also times each method's serial ``run_method``
    slice (one scanned program per method, same per-method PRNG keys as
    the sweep rows) and records the sweep-vs-serial accuracy parity;
    ``paper_budget_oracle`` additionally runs the old host-loop
    ``train_centralized`` with the paper's clinic-scaled step budget
    (the sweep's centralized row is same-budget by design — see
    engine.method_params); ``bench_json`` writes BENCH_sweep.json.
    """
    clients = make_dr_swarm_data(image_size=image_size, seed=seed,
                                 table=scale_table(data_scale))
    model = build_model(get_config("squeezenet-dr"))
    swarm = SwarmConfig(n_clients=14, n_clusters=3, rounds=rounds,
                        local_steps=local_steps)
    opt = OptimizerConfig(name="adam", lr=2e-3)
    cfg, data = make_method_setup(model, clients, swarm, opt, batch_size=8)
    test_stack = stack_eval_split(model.cfg, clients, "test")
    key = jax.random.PRNGKey(seed)

    # --- the sweep: whole Table II, ONE device program
    t0 = time.time()
    results, _ = run_sweep_table(model, clients, swarm, opt, key,
                                 batch_size=8, cfg=cfg, data=data,
                                 test_stack=test_stack)
    us_sweep = (time.time() - t0) * 1e6
    for method in METHODS:
        row(f"table2/{method}", us_sweep / len(METHODS),
            f"acc={results[method]:.4f};paper_acc={PAPER[method]:.4f}")
    row("table2/sweep_program", us_sweep,
        f"programs=1;methods={len(METHODS)};rounds={rounds}")

    # --- serial reference: one scanned program per method, same keys
    serial, us_serial = {}, {}
    if serial_reference:
        keys = sweep_keys(key, METHODS)
        for i, method in enumerate(METHODS):
            t0 = time.time()
            acc, _ = run_method(method, model, clients, swarm, opt, keys[i],
                                batch_size=8, verbose=verbose,
                                cfg=cfg, data=data, test_stack=test_stack)
            us_serial[method] = (time.time() - t0) * 1e6
            serial[method] = acc
            row(f"table2/serial/{method}", us_serial[method],
                f"acc={acc:.4f};sweep_acc={results[method]:.4f}")
        parity = max(abs(serial[m] - results[m]) for m in METHODS)
        row("table2/sweep_serial_parity", 0.0, f"max_abs_acc_diff={parity:.2e}")

    # --- paper-budget centralized oracle: the pre-sweep host loop whose
    # step count scales with the clinic count (N x the axis budget)
    oracle_acc = None
    if paper_budget_oracle:
        steps = rounds * int(np.ceil(np.mean(
            [c["n_train"] for c in clients]) / 8)) * len(clients)
        t0 = time.time()
        _, oracle_acc = train_centralized(model, clients, opt,
                                          jax.random.PRNGKey(seed),
                                          steps=steps, batch_size=8)
        row("table2/centralized_paper_budget", (time.time() - t0) * 1e6,
            f"acc={oracle_acc:.4f};steps={steps};"
            f"axis_steps={rounds * local_steps}")

    if bench_json:
        artifact = {
            "methods": METHODS,
            "n_clients": swarm.n_clients,
            "rounds": rounds,
            "local_steps": local_steps,
            "batch_size": 8,
            "data_scale": data_scale,
            "accs_sweep": results,
            "accs_serial": serial,
            "paper_accs": PAPER,
            "us_sweep_program": us_sweep,
            "us_serial_per_method": us_serial,
            "us_serial_total": sum(us_serial.values()),
            # before the sweep engine: one dispatch per round per method
            # (+ the centralized host loop's per-step dispatches)
            "programs_before": len(METHODS) * rounds,
            "programs_serial_run_method": len(METHODS),
            "programs_sweep": 1,
            "parity_max_abs_acc_diff":
                max(abs(serial[m] - results[m]) for m in METHODS)
                if serial else None,
            "acc_centralized_paper_budget": oracle_acc,
            # validated orderings under this repro's Eq.3 per-client
            # protocol (the paper's literal local-lowest ordering is a
            # documented non-reproduction: tiny non-IID clinics reward
            # local overfitting; and the axis centralizes at the SAME
            # budget as the federated methods, unlike the paper's
            # clinic-scaled centralized run — see the oracle field)
            "ordering_tol": ORDERING_TOL,
            "ordering": {
                "centralized_upper_bounds_global_fedavg":
                    results["centralized"]
                    >= results["fedavg"] - ORDERING_TOL,
                "bso_over_fedavg":
                    results["bso-sl"] >= results["fedavg"] - ORDERING_TOL,
                "federated_above_random_floor":
                    results["bso-sl"] > 0.25 and results["fedavg"] > 0.2,
                "local_overfits_protocol_artifact":
                    results["local"] > results["centralized"],
            },
            "note": "Wall-clocks are end-to-end (compile + run) on the "
                    "CPU backend; the transferable win is the program "
                    "collapse (4 methods x rounds dispatches -> 1 "
                    "vmapped executable sharing one SwarmData), same "
                    "as BENCH_round.json's dispatch-count story.",
        }
        with open(bench_json, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"[table2_methods] wrote {bench_json}")
    return results


def main():
    results = run(paper_budget_oracle=True, bench_json="BENCH_sweep.json")
    # Validated qualitative claims under this repro's protocol (the
    # paper's local-lowest ordering is not reproducible with a
    # competent local trainer under the per-client Eq.3 protocol, and
    # the axis centralizes at the same step budget as the federated
    # methods — the paper-budget host loop is reported separately as
    # table2/centralized_paper_budget):
    #   (1) centralized upper-bounds the global-model baseline (FedAvg)
    #       — pooled IID sampling vs non-IID client averaging,
    #   (2) BSO-SL >= FedAvg (clustered aggregation handles label skew),
    #   (3) both federated methods clear the 5-class random floor.
    ok = (results["centralized"] >= results["fedavg"] - ORDERING_TOL and
          results["bso-sl"] >= results["fedavg"] - ORDERING_TOL and
          results["bso-sl"] > 0.25 and results["fedavg"] > 0.2)
    row("table2/ordering_check", 0.0, f"validated_claims_hold={ok}")


if __name__ == "__main__":
    main()
