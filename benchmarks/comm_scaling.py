"""The scalability/communication claim (paper §I, §III.B).

Per-round communication for N clients, model with P params (4-byte):

  blockchain swarm learning — every client broadcasts its full model to
      every other client: N*(N-1)*P*4 bytes (+ mining work, not modelled)
  FedAvg                    — 2*N*P*4 (up + down via server)
  BSO-SL                    — coordinator traffic N*(2*T)*4 (T = tensor
      count, the distribution summaries) + intra-cluster exchange
      ~ 2*N*P*4 client-to-client, but NO server and NO O(N^2) broadcast.

The benchmark measures the *actual* byte counts from the implementation
(diststats.upload_bytes / full_params_bytes) across the assigned archs,
plus the measured wall-time of the coordinator stage (stats + k-means +
brain storm) to show it stays negligible as N grows.

``--fleet`` (its own process: it forces the 8-device CPU stand-in)
runs the end-to-end fleet driver instead and writes ``BENCH_fleet.json``
— per-round stat-upload vs Eq. 2 aggregation traffic measured from the
ONE compiled fleet-round executable (see ``repro.launch.fleet_driver``
and docs/BENCHMARKS.md).
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.configs import get_config
from repro.core.bso import brain_storm
from repro.core.diststats import full_params_bytes, param_distribution, upload_bytes
from repro.core.kmeans import kmeans
from repro.models import build_model


def model_comm_table():
    import dataclasses
    for arch in ["squeezenet-dr", "granite-3-2b", "deepseek-7b",
                 "command-r-35b", "kimi-k2-1t-a32b"]:
        cfg = get_config(arch)
        if cfg.family != "cnn":
            # per-layer tensor counts (not scan-stacked) for honest
            # coordinator-message sizing
            cfg = dataclasses.replace(cfg, scan_layers=False)
        model = build_model(cfg)
        params = jax.eval_shape(lambda m=model: m.init(jax.random.PRNGKey(0)))
        up = upload_bytes(params)
        full = full_params_bytes(params)
        n = 14
        bc = (n - 1) * n * full              # blockchain all-broadcast
        fa = 2 * n * full                    # fedavg
        bso_coord = n * up                   # BSO-SL coordinator traffic
        bso_p2p = 2 * n * full               # intra-cluster exchange bound
        row(f"comm/{arch}", 0.0,
            f"stats_up_B={up};full_params_B={full};"
            f"blockchain_B={bc:.3e};fedavg_B={fa:.3e};"
            f"bso_coord_B={bso_coord:.3e};bso_p2p_B={bso_p2p:.3e};"
            f"coord_reduction_x={full/max(up,1):.0f}")


def coordinator_scaling():
    """Coordinator wall-time vs N on a SqueezeNet-sized feature vector."""
    cfg = get_config("squeezenet-dr")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    feats1 = param_distribution(params)
    F = feats1.shape[0]
    rng = np.random.default_rng(0)
    for n in (14, 64, 256, 1024):
        X = jnp.asarray(rng.normal(size=(n, F)), jnp.float32)
        km = jax.jit(lambda key, X: kmeans(key, X, 3, 20))
        _, us = timed(km, jax.random.PRNGKey(0), X, warmup=1, iters=3)
        t0 = time.perf_counter()
        a = np.asarray(km(jax.random.PRNGKey(0), X)[1])
        brain_storm(np.random.default_rng(0), a,
                    rng.uniform(size=n).astype(np.float32), 3, 0.9, 0.8)
        bs_us = (time.perf_counter() - t0) * 1e6
        row(f"comm/coordinator_n{n}", us,
            f"kmeans_us={us:.0f};brainstorm_us={bs_us:.0f};features={F}")


def fleet_bench(n_clients: int = 8, rounds: int = 3, data_scale: int = 16,
                image_size: int = 16, local_steps: int = 4,
                batch_size: int = 8, seed: int = 0,
                out_json: str = "BENCH_fleet.json"):
    """End-to-end fleet traffic: drive ``rounds`` full BSO-SL rounds
    (``repro.launch.fleet_driver``) and record, per round, the tiny
    host-facing coordinator traffic against the on-mesh Eq. 2
    aggregation traffic of the ONE compiled round executable. Needs a
    multi-device backend for a non-trivial pod axis — run via
    ``python -m benchmarks.comm_scaling --fleet`` (own process, forces
    the 8-device stand-in), NOT from the ``benchmarks.run`` suite."""
    from repro.launch.fleet_driver import make_unit_fleet, run_fleet

    model, opt, mesh, clients = make_unit_fleet(
        n_clients, image_size=image_size, data_scale=data_scale, seed=seed)
    res = run_fleet(model, opt, mesh, clients, rounds=rounds,
                    local_steps=local_steps, batch_size=batch_size,
                    seed=seed)
    comm = res.comm
    per_round = [
        {"round": r.round, "mean_val_acc": r.mean_val_acc,
         "train_loss": r.train_loss,
         "stat_upload_bytes": comm["stat_upload_bytes"],
         "coordinator_roundtrip_bytes": comm["stat_upload_bytes"]
         + comm["val_upload_bytes"] + comm["cluster_feedback_bytes"],
         "eq2_collective_bytes_per_device":
             comm["eq2_collective_bytes"]["total"],
         "n_bsa_events": len(r.events),
         "us_round": r.wall_s * 1e6, "us_coordinator": r.coord_s * 1e6}
        for r in res.history]
    artifact = {
        **res.meta,
        "data_scale": data_scale,
        "n_compiles": res.n_compiles,
        "compile_s": res.compile_s,
        "per_round": per_round,
        "comm": comm,
        "note": "one executable for all rounds; the coordinator "
                "round-trip (stats up, clusters down) is the ONLY "
                "host-facing model-derived traffic — Eq. 2 stays on the "
                "mesh as collectives (paper §III.B). Byte columns are "
                "per round; collective bytes are per device from the "
                "optimized-HLO census (launch.comm).",
    }
    for pr in per_round:
        row(f"fleet/round{pr['round']}", pr["us_round"],
            f"val_acc={pr['mean_val_acc']:.4f};"
            f"stats_up_B={pr['stat_upload_bytes']};"
            f"eq2_coll_B={pr['eq2_collective_bytes_per_device']};"
            f"coord_us={pr['us_coordinator']:.0f}")
    row("fleet/summary", res.compile_s * 1e6,
        f"n_compiles={res.n_compiles};"
        f"coord_reduction_x={comm['coord_reduction_x']:.0f};"
        f"devices={res.meta['n_devices']}")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"[fleet_bench] wrote {out_json}")
    return artifact


def main():
    model_comm_table()
    coordinator_scaling()


def _cli():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fleet", action="store_true",
                    help="run the end-to-end fleet driver benchmark and "
                         "write BENCH_fleet.json (forces the 8-device "
                         "CPU stand-in; run standalone)")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--no-artifacts", action="store_true",
                    help="don't write BENCH_fleet.json")
    args = ap.parse_args()
    if args.fleet:
        from repro.launch.swarm_fleet import force_host_device_count
        force_host_device_count(8)
        print("name,us_per_call,derived")
        fleet_bench(rounds=args.rounds,
                    out_json=None if args.no_artifacts
                    else "BENCH_fleet.json")
        return
    print("name,us_per_call,derived")
    main()


if __name__ == "__main__":
    _cli()
