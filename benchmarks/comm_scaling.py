"""The scalability/communication claim (paper §I, §III.B).

Per-round communication for N clients, model with P params (4-byte):

  blockchain swarm learning — every client broadcasts its full model to
      every other client: N*(N-1)*P*4 bytes (+ mining work, not modelled)
  FedAvg                    — 2*N*P*4 (up + down via server)
  BSO-SL                    — coordinator traffic N*(2*T)*4 (T = tensor
      count, the distribution summaries) + intra-cluster exchange
      ~ 2*N*P*4 client-to-client, but NO server and NO O(N^2) broadcast.

The benchmark measures the *actual* byte counts from the implementation
(diststats.upload_bytes / full_params_bytes) across the assigned archs,
plus the measured wall-time of the coordinator stage (stats + k-means +
brain storm) to show it stays negligible as N grows.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.configs import get_config
from repro.core.bso import brain_storm
from repro.core.diststats import full_params_bytes, param_distribution, upload_bytes
from repro.core.kmeans import kmeans
from repro.models import build_model


def model_comm_table():
    import dataclasses
    for arch in ["squeezenet-dr", "granite-3-2b", "deepseek-7b",
                 "command-r-35b", "kimi-k2-1t-a32b"]:
        cfg = get_config(arch)
        if cfg.family != "cnn":
            # per-layer tensor counts (not scan-stacked) for honest
            # coordinator-message sizing
            cfg = dataclasses.replace(cfg, scan_layers=False)
        model = build_model(cfg)
        params = jax.eval_shape(lambda m=model: m.init(jax.random.PRNGKey(0)))
        up = upload_bytes(params)
        full = full_params_bytes(params)
        n = 14
        bc = (n - 1) * n * full              # blockchain all-broadcast
        fa = 2 * n * full                    # fedavg
        bso_coord = n * up                   # BSO-SL coordinator traffic
        bso_p2p = 2 * n * full               # intra-cluster exchange bound
        row(f"comm/{arch}", 0.0,
            f"stats_up_B={up};full_params_B={full};"
            f"blockchain_B={bc:.3e};fedavg_B={fa:.3e};"
            f"bso_coord_B={bso_coord:.3e};bso_p2p_B={bso_p2p:.3e};"
            f"coord_reduction_x={full/max(up,1):.0f}")


def coordinator_scaling():
    """Coordinator wall-time vs N on a SqueezeNet-sized feature vector."""
    cfg = get_config("squeezenet-dr")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    feats1 = param_distribution(params)
    F = feats1.shape[0]
    rng = np.random.default_rng(0)
    for n in (14, 64, 256, 1024):
        X = jnp.asarray(rng.normal(size=(n, F)), jnp.float32)
        km = jax.jit(lambda key, X: kmeans(key, X, 3, 20))
        _, us = timed(km, jax.random.PRNGKey(0), X, warmup=1, iters=3)
        t0 = time.perf_counter()
        a = np.asarray(km(jax.random.PRNGKey(0), X)[1])
        brain_storm(np.random.default_rng(0), a,
                    rng.uniform(size=n).astype(np.float32), 3, 0.9, 0.8)
        bs_us = (time.perf_counter() - t0) * 1e6
        row(f"comm/coordinator_n{n}", us,
            f"kmeans_us={us:.0f};brainstorm_us={bs_us:.0f};features={F}")


def main():
    model_comm_table()
    coordinator_scaling()


if __name__ == "__main__":
    main()
