"""Shared benchmark utilities."""
from __future__ import annotations

import time

import jax


def timed(fn, *args, warmup: int = 1, iters: int = 5):
    """Returns (result, us_per_call)."""
    res = None
    for _ in range(warmup):
        res = fn(*args)
    jax.block_until_ready(res)
    t0 = time.perf_counter()
    for _ in range(iters):
        res = fn(*args)
    jax.block_until_ready(res)
    dt = (time.perf_counter() - t0) / iters
    return res, dt * 1e6


def row(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")
