"""Churn-robustness benchmark (PR 8): final accuracy vs client dropout,
plain vs staleness-weighted Eq. 2 — the whole sweep as ONE executable.

The scenario axis rides the grid engine: every (dropout, stale_decay)
point is a :func:`repro.core.engine.grid_point` row of one vmapped
``run_grid`` program (compile census pinned below), exactly like the
k/p1 ablation in ``cluster_ablation`` — a robustness sweep costs one
compile, not |grid| serial fits. Two Eq. 2 weightings per dropout
level:

  * ``stale_decay=0.0`` — the hard participation mask: absent clients
    carry zero weight (0^0 == 1 keeps fresh clients whole),
  * ``stale_decay=λ>0`` — the staleness-weighted variant: an absent
    client keeps |D_h|·λ^staleness, a decayed echo of its last
    contribution.

The sweep's anchor is the BITWISE all-ones check: the ``dropout=0``
row of the churn grid must reproduce the churn-free ``run_grid_point``
(no ChurnParams at all) bit-for-bit — masks are float identities, keys
are consumed unconditionally — so the dropout>0 rows measure churn and
nothing else. Writes ``BENCH_churn.json``.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from benchmarks.common import row
from repro.configs import get_config
from repro.configs.base import OptimizerConfig, SwarmConfig
from repro.core.baselines import run_grid_point, run_grid_table, sweep_keys
from repro.core.engine import jit_run_grid
from repro.data.dr import make_dr_swarm_data, scale_table
from repro.models import build_model

#: the acceptance sweep: dropout x Eq. 2 weighting
DROPOUTS = (0.0, 0.2, 0.4, 0.6)
STALE_DECAYS = (0.0, 0.5)


def run(data_scale: int = 4, rounds: int = 4, local_steps: int = 6,
        seed: int = 0, dropouts=DROPOUTS, stale_decays=STALE_DECAYS,
        out_json: str = "BENCH_churn.json"):
    """The dropout x stale-decay churn sweep as ONE run_grid program,
    with the bitwise all-ones anchor against the churn-free serial
    oracle and a compile census."""
    clients = make_dr_swarm_data(image_size=16, seed=seed,
                                 table=scale_table(data_scale))
    model = build_model(get_config("squeezenet-dr"))
    opt = OptimizerConfig(name="adam", lr=2e-3)
    swarm = SwarmConfig(n_clients=len(clients), rounds=rounds,
                        local_steps=local_steps)
    specs = [{"dropout": d, "stale_decay": s}
             for s in stale_decays for d in dropouts]
    key = jax.random.PRNGKey(seed)

    n0 = jit_run_grid._cache_size()
    t0 = time.time()
    results, grid_run = run_grid_table(model, clients, swarm, opt, key,
                                       specs=specs, batch_size=8)
    us_grid = (time.time() - t0) * 1e6
    n_programs = jit_run_grid._cache_size() - n0
    final_val = np.asarray(grid_run.metrics.mean_val_acc)[:, -1]
    present = np.asarray(grid_run.metrics.present)      # (G, rounds, N)
    for g, (spec, res) in enumerate(zip(specs, results)):
        row(f"churn/drop{spec['dropout']}_decay{spec['stale_decay']}",
            us_grid / len(specs),
            f"acc={res['acc']:.4f};final_val={final_val[g]:.4f};"
            f"presence={present[g].mean():.2f}")
    row("churn/one_program", us_grid,
        f"programs={n_programs};points={len(specs)};rounds={rounds}")

    # the bitwise anchor: churn row (dropout=0, stale_decay=0) ==
    # the churn-free serial fit with the same key, bit for bit
    keys = sweep_keys(key, specs)
    g0 = specs.index({"dropout": 0.0, "stale_decay": 0.0}) \
        if {"dropout": 0.0, "stale_decay": 0.0} in specs else 0
    acc_ref, ref = run_grid_point({}, model, clients, swarm, opt,
                                  keys[g0], batch_size=8)
    bitwise = True
    for x, y in zip(jax.tree.leaves(
            jax.tree.map(lambda v: v[g0], grid_run.state.params)),
            jax.tree.leaves(ref.state.params)):
        bitwise &= bool(np.array_equal(np.asarray(x), np.asarray(y)))
    bitwise &= results[g0]["acc"] == acc_ref
    row("churn/allones_bitwise", 0.0, f"equal={bitwise}")

    artifact = {
        "dropouts": list(dropouts),
        "stale_decays": list(stale_decays),
        "points": [{k: v for k, v in r.items() if k != "acc"}
                   for r in results],
        "n_clients": swarm.n_clients,
        "rounds": rounds,
        "local_steps": local_steps,
        "batch_size": 8,
        "data_scale": data_scale,
        "accs_test": [r["acc"] for r in results],
        "final_val_accs": final_val.tolist(),
        "presence_rates": present.mean(axis=(1, 2)).tolist(),
        "us_grid_program": us_grid,
        "programs_grid": n_programs,
        "allones_bitwise_vs_unmasked": bitwise,
        "note": "Each point is a grid row of ONE vmapped run_grid "
                "executable (the same program collapse as "
                "BENCH_grid.json, extended to the churn scenario axes). "
                "dropout Bernoulli-drops clients per round from a "
                "fold_in-derived key that consumes nothing from the "
                "training stream; stale_decay=0 is the hard "
                "participation mask, >0 the staleness-weighted Eq. 2 "
                "(|D_h|*decay^staleness). allones_bitwise_vs_unmasked "
                "certifies the dropout=0 row reproduces the churn-free "
                "serial fit bit-for-bit, so the accuracy deltas across "
                "dropout measure churn robustness and nothing else. "
                "CPU-backend wall-clocks, small data scale — the accs "
                "are trend indicators, not paper numbers.",
    }
    if out_json:
        with open(out_json, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"[churn_bench] wrote {out_json}")
    return artifact


def main():
    return run()


if __name__ == "__main__":
    main()
