"""Serving benchmark (PR 7): the continuous-batching engine under a
mixed-length request workload — throughput and request-latency
percentiles vs slot batch size and bucket layout, plus the
compile-count census proving the per-bucket program budget (exactly
one prefill + one decode executable per bucket, zero steady-state
retraces).

Writes ``BENCH_serve.json``. The LM sweep drives ``repro.serve``'s
``ServeEngine`` over several bucket layouts at the same total slot
budget; the CNN sweep drives ``ImageClassifier`` over batch buckets —
the DR-grading scoring path of the source paper.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from benchmarks.common import row
from repro.configs import get_config
from repro.models import build_model
from repro.serve import BucketSpec, ImageClassifier, Request, ServeEngine


def _pcts(xs):
    xs = np.asarray(xs, np.float64)
    return {f"p{p}": float(np.percentile(xs, p)) for p in (50, 95, 99)}


def _workload(n_requests, max_seq, max_new, vocab, seed):
    rng = np.random.default_rng(seed)
    lens = rng.integers(2, max_seq - max_new, size=n_requests)
    return [Request(rid=i, prompt=rng.integers(0, vocab, size=int(n)),
                    max_new_tokens=max_new)
            for i, n in enumerate(lens)]


def _lm_layouts(max_seq, slots):
    """Same total slot budget, different shapes: one flat bucket, a
    pow2 two-bucket ladder, and a half-batch variant."""
    half = max(1, slots // 2)
    return {
        f"flat_b{slots}": (BucketSpec(slots, max_seq),),
        "ladder_2": (BucketSpec(half, max_seq // 2),
                     BucketSpec(slots - half, max_seq)),
        f"flat_b{half}": (BucketSpec(half, max_seq),),
    }


def run(arch: str = "granite-3-2b", n_requests: int = 24,
        max_new: int = 8, max_seq: int = 64, slots: int = 8,
        seed: int = 0, use_pallas: bool = False,
        cnn_requests: int = 32, cnn_buckets=(1, 4, 8),
        out_json: str | None = "BENCH_serve.json"):
    cfg = get_config(arch).smoke()
    if use_pallas:
        import dataclasses
        cfg = dataclasses.replace(cfg, use_pallas=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    reqs = _workload(n_requests, max_seq, max_new, cfg.vocab_size, seed)

    lm_rows = []
    ref_tokens = None
    for name, buckets in _lm_layouts(max_seq, slots).items():
        engine = ServeEngine(model, params, buckets)
        t0 = time.perf_counter()
        for r in reqs:
            r.t_submit = r.t_admit = r.t_first = r.t_done = 0.0
            engine.submit(r)
        engine.run_until_drained()
        wall = time.perf_counter() - t0
        res = [engine.results[i] for i in range(n_requests)]
        toks = [r.tokens for r in res]
        if ref_tokens is None:
            ref_tokens = toks
        n_tok = sum(len(t) for t in toks)
        cc = engine.compile_counts()
        budget_ok = all(v == {"prefill": 1, "decode": 1}
                        for v in cc.values())
        lat = _pcts([r.latency for r in res])
        ttft = _pcts([r.ttft for r in res])
        lm_rows.append({
            "layout": name,
            "buckets": [{"batch": b.batch, "seq": b.seq,
                         "name": b.name} for b in buckets],
            "n_requests": n_requests,
            "generated_tokens": n_tok,
            "wall_s": wall,
            "tok_per_s": n_tok / wall,
            "req_per_s": n_requests / wall,
            "latency_s": lat,
            "ttft_s": ttft,
            "ticks": {"prefill": engine.n_prefill_calls,
                      "decode": engine.n_decode_calls},
            "compile_counts": cc,
            "program_budget_ok": budget_ok,
            "tokens_match_flat": toks == ref_tokens,
        })
        row(f"serve/lm_{name}", wall * 1e6,
            f"tok_s={n_tok / wall:.1f};p50={lat['p50'] * 1e3:.0f}ms;"
            f"p99={lat['p99'] * 1e3:.0f}ms;budget_ok={budget_ok}")

    # CNN scoring path: throughput vs batch-bucket set
    cnn_cfg = get_config("squeezenet-dr")
    cnn_model = build_model(cnn_cfg)
    cnn_params = cnn_model.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed + 1)
    imgs = rng.normal(size=(cnn_requests, 32, 32, 3)).astype(np.float32)
    cnn_rows = []
    for bset in ({"buckets": (cnn_buckets[0],)},
                 {"buckets": tuple(cnn_buckets)}):
        clf = ImageClassifier(cnn_model, cnn_params, bset["buckets"])
        creqs = [Request(rid=i, image=imgs[i]) for i in range(cnn_requests)]
        t0 = time.perf_counter()
        clf.classify(creqs)
        wall = time.perf_counter() - t0
        lat = _pcts([r.latency for r in clf.results.values()])
        cnn_rows.append({
            "batch_buckets": list(bset["buckets"]),
            "n_images": cnn_requests,
            "wall_s": wall,
            "img_per_s": cnn_requests / wall,
            "latency_s": lat,
            "compile_counts": clf.compile_counts(),
        })
        row(f"serve/cnn_b{'_'.join(map(str, bset['buckets']))}",
            wall * 1e6, f"img_s={cnn_requests / wall:.1f};"
            f"p50={lat['p50'] * 1e3:.0f}ms")

    artifact = {
        "arch": cfg.arch_id,
        "use_pallas": use_pallas,
        "max_new_tokens": max_new,
        "max_seq": max_seq,
        "slots": slots,
        "lm": lm_rows,
        "cnn": cnn_rows,
        "note": "Every LM layout serves the identical mixed-length "
                "request set; tokens_match_flat pins greedy-output "
                "invariance across layouts. program_budget_ok asserts "
                "the zero-retrace property: after draining the whole "
                "workload each bucket holds exactly 1 compiled prefill "
                "+ 1 compiled decode executable. Latency percentiles "
                "are per-request submit->done (queue wait included); "
                "ttft is submit->first-token.",
    }
    budget_all = all(r["program_budget_ok"] for r in lm_rows)
    row("serve/program_budget", 0.0, f"all_buckets_1prefill_1decode={budget_all}")
    if not budget_all:
        raise RuntimeError(f"per-bucket program budget violated: "
                           f"{[r['compile_counts'] for r in lm_rows]}")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"[serve_bench] wrote {out_json}")
    return artifact


def main() -> None:
    run()


if __name__ == "__main__":
    main()
